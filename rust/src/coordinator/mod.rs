//! The coordination layer (Layer 3): the parallel Gibbs sweep over one
//! *mode* of the model (a matrix's rows or columns, or mode m of an
//! N-mode tensor view), the engine abstraction that lets the same sweep
//! run on the native Rust kernels or on the AOT-compiled XLA artifacts,
//! and the fork-join [`ThreadPool`] standing in for OpenMP.
//!
//! The MVN row conditional never sees matrices vs tensors: per observed
//! entry it consumes a *design row* through [`Operand`] — the opposite
//! side's latent row for matrices, the Hadamard product of the other
//! modes' latent rows (built in per-thread scratch, no per-row
//! allocation) for tensors — so [`sample_one_row_mvn`], the engines and
//! [`view_sse`] are shared by both paths rather than forked.
//!
//! Determinism invariant (DESIGN.md §5, property-tested in
//! `rust/tests/coordinator_props.rs`): every row i of iteration t draws
//! from `Rng::for_row(seed, t, side, i)`, so the sampled latents are
//! identical for any thread count and any schedule.

pub mod threadpool;

pub use threadpool::ThreadPool;

use crate::data::MatrixConfig;
use crate::linalg::Mat;
use crate::noise::NoiseModel;
use crate::priors::{MeanSpec, Prior, RowObs};
use crate::rng::Rng;
use crate::sparse::SparseTensor;

/// How the rows of the side being updated see one data view.
pub enum DataAccess<'a> {
    /// target rows are matrix rows (CSR view)
    SparseRows(&'a crate::sparse::SparseMatrix),
    /// target rows are matrix columns (CSC view)
    SparseCols(&'a crate::sparse::SparseMatrix),
    /// dense data, target rows are matrix rows
    DenseRows(&'a Mat),
    /// dense data, target rows are matrix columns
    DenseCols(&'a Mat),
}

impl<'a> DataAccess<'a> {
    /// Number of observed entries for target row i.
    pub fn nnz(&self, i: usize) -> usize {
        match self {
            DataAccess::SparseRows(m) => m.row_nnz(i),
            DataAccess::SparseCols(m) => m.col_nnz(i),
            DataAccess::DenseRows(m) => m.cols(),
            DataAccess::DenseCols(m) => m.rows(),
        }
    }

    /// Visit every observed (other_index, value) of target row i.
    #[inline]
    pub fn for_each_obs<F: FnMut(usize, f64)>(&self, i: usize, mut f: F) {
        match self {
            DataAccess::SparseRows(m) => {
                let (idx, vals) = m.row(i);
                for (&j, &v) in idx.iter().zip(vals) {
                    f(j as usize, v);
                }
            }
            DataAccess::SparseCols(m) => {
                let (idx, vals) = m.col(i);
                for (&j, &v) in idx.iter().zip(vals) {
                    f(j as usize, v);
                }
            }
            DataAccess::DenseRows(m) => {
                for (j, &v) in m.row(i).iter().enumerate() {
                    f(j, v);
                }
            }
            DataAccess::DenseCols(m) => {
                for j in 0..m.rows() {
                    f(j, m[(j, i)]);
                }
            }
        }
    }

    /// Gather (idx, vals) into scratch vectors (used by custom samplers
    /// and the XLA engine's block marshalling).
    pub fn gather(&self, i: usize, idx: &mut Vec<u32>, vals: &mut Vec<f64>) {
        idx.clear();
        vals.clear();
        self.for_each_obs(i, |j, v| {
            idx.push(j as u32);
            vals.push(v);
        });
    }
}

/// Mode m of a tensor view as seen from the sweep updating that mode:
/// the design row of an observation is the Hadamard product of the
/// *other* modes' latent rows at the observation's coordinates.
pub struct TensorModeOperand<'a> {
    pub tensor: &'a SparseTensor,
    /// the mode being updated
    pub mode: usize,
    /// (mode id, factor matrix) for every mode except `mode`, ascending
    pub others: Vec<(usize, &'a Mat)>,
}

/// How the target rows of the mode being updated see one data view: per
/// observed entry the MVN conditional consumes a *design row*.
pub enum Operand<'a> {
    /// 2-mode case — design row = `other.row(j)` for observation (i, j)
    Matrix {
        data: DataAccess<'a>,
        /// the opposite side's latents
        other: &'a Mat,
    },
    /// N-mode case — design rows built per observation in caller scratch
    TensorMode(TensorModeOperand<'a>),
}

impl<'a> Operand<'a> {
    /// Number of observed entries for target index i.
    pub fn nnz(&self, i: usize) -> usize {
        match self {
            Operand::Matrix { data, .. } => data.nnz(i),
            Operand::TensorMode(t) => t.tensor.mode_nnz(t.mode, i),
        }
    }

    /// Latent dimension K of the design rows.
    pub fn k(&self) -> usize {
        match self {
            Operand::Matrix { other, .. } => other.cols(),
            Operand::TensorMode(t) => t.others[0].1.cols(),
        }
    }

    /// Visit every observation of target index i as (design row, value).
    /// `scratch` backs the ≥3-mode Hadamard products; matrices and
    /// 2-mode tensors hand out factor rows directly without copying, so
    /// the 2-mode tensor path is bit-identical to the matrix path.
    #[inline]
    pub fn for_each_design<F: FnMut(&[f64], f64)>(
        &self,
        i: usize,
        scratch: &mut Vec<f64>,
        mut f: F,
    ) {
        match self {
            Operand::Matrix { data, other } => {
                data.for_each_obs(i, |j, v| f(other.row(j), v));
            }
            Operand::TensorMode(t) => {
                let fiber = t.tensor.mode_fiber(t.mode, i);
                if t.others.len() == 1 {
                    // exactly one other mode: its latent row IS the design
                    let (om, fac) = t.others[0];
                    for &e in fiber {
                        let e = e as usize;
                        f(fac.row(t.tensor.coord(om, e) as usize), t.tensor.val(e));
                    }
                    return;
                }
                let k = self.k();
                scratch.resize(k, 0.0);
                let (&(m0, f0), rest) = t.others.split_first().expect("≥2 other modes");
                for &e in fiber {
                    let e = e as usize;
                    scratch.copy_from_slice(f0.row(t.tensor.coord(m0, e) as usize));
                    for &(m, fac) in rest {
                        let frow = fac.row(t.tensor.coord(m, e) as usize);
                        for (s, &x) in scratch.iter_mut().zip(frow) {
                            *s *= x;
                        }
                    }
                    f(&scratch[..], t.tensor.val(e));
                }
            }
        }
    }

    /// The matrix parts (data access + opposite-side latents) when this
    /// is the 2-mode operand — the XLA engine's fast-path gate.
    pub fn matrix_parts(&self) -> Option<(&DataAccess<'a>, &'a Mat)> {
        match self {
            Operand::Matrix { data, other } => Some((data, *other)),
            Operand::TensorMode(_) => None,
        }
    }
}

/// One data view as seen from the mode being updated.
pub struct ViewSlice<'a> {
    pub operand: Operand<'a>,
    /// likelihood precision of this view
    pub alpha: f64,
    /// probit augmentation (binary data)?
    pub probit: bool,
    /// α · OᵀO precomputed when the view is fully observed (the
    /// "sparse fully known" / "dense" fast path of Table 1)
    pub full_gram: Option<Mat>,
}

impl<'a> ViewSlice<'a> {
    /// The 2-mode slice: target rows see `data`, design rows come from
    /// the opposite side's latents `other`.
    pub fn matrix(
        data: DataAccess<'a>,
        other: &'a Mat,
        alpha: f64,
        probit: bool,
        full_gram: Option<Mat>,
    ) -> ViewSlice<'a> {
        ViewSlice { operand: Operand::Matrix { data, other }, alpha, probit, full_gram }
    }

    /// Mode `mode` of an N-mode tensor view; `others` pairs every other
    /// mode id with its factor matrix, ascending.
    pub fn tensor_mode(
        tensor: &'a SparseTensor,
        mode: usize,
        others: Vec<(usize, &'a Mat)>,
        alpha: f64,
        probit: bool,
    ) -> ViewSlice<'a> {
        assert_eq!(
            others.len() + 1,
            tensor.nmodes(),
            "tensor slice needs one factor per other mode"
        );
        ViewSlice {
            operand: Operand::TensorMode(TensorModeOperand { tensor, mode, others }),
            alpha,
            probit,
            full_gram: None,
        }
    }

    /// Precompute the full-gram fast path for fully-observed data.
    pub fn full_gram_for(other: &Mat, alpha: f64) -> Mat {
        let mut g = crate::linalg::syrk(other, crate::linalg::Backend::global());
        g.scale(alpha);
        g
    }
}

/// Everything an engine needs to resample one side with MVN conditionals.
pub struct MvnSweep<'a> {
    pub lambda0: &'a Mat,
    pub means: MeanSpec<'a>,
    pub views: Vec<ViewSlice<'a>>,
    pub seed: u64,
    pub iteration: u64,
    /// 0 = rows side, 1.. = column side of view v-1
    pub side_id: u64,
}

/// A sampling engine: resamples all rows of `latents` in place.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;
    fn sample_mvn_side(&self, sweep: &MvnSweep<'_>, latents: &mut Mat, pool: &ThreadPool);

    /// Resample only `rows` (a distributed shard's block).  Must draw
    /// exactly the values `sample_mvn_side` would draw for those rows —
    /// guaranteed here because every row i uses `Rng::for_row(seed, t,
    /// side, i)` regardless of which node (or engine) samples it.  The
    /// full range delegates to `sample_mvn_side` so engine fast paths
    /// (XLA blocking) still apply to single-node sweeps.
    fn sample_mvn_side_range(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
    ) {
        if rows.start == 0 && rows.end == latents.rows() {
            return self.sample_mvn_side(sweep, latents, pool);
        }
        let k = latents.cols();
        let writer = RowWriter::new(latents);
        let start = rows.start;
        pool.parallel_for(rows.len(), 1, |t| {
            let i = start + t;
            let mut rng = Rng::for_row(sweep.seed, sweep.iteration, sweep.side_id, i as u64);
            // SAFETY: each i is visited exactly once (threadpool contract)
            let row = unsafe { writer.row_mut(i) };
            sample_one_row_mvn(sweep, i, row, k, &mut rng);
        });
    }
}

/// Shared mutable row access for disjoint parallel row writes.
pub struct RowWriter {
    ptr: *mut f64,
    cols: usize,
    #[allow(dead_code)]
    rows: usize,
}

unsafe impl Send for RowWriter {}
unsafe impl Sync for RowWriter {}

impl RowWriter {
    pub fn new(m: &mut Mat) -> RowWriter {
        RowWriter { ptr: m.data_mut().as_mut_ptr(), cols: m.cols(), rows: m.rows() }
    }

    /// # Safety
    /// Each row index must be accessed by at most one thread at a time.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

/// The pure-Rust engine: per-row Gram accumulation (the native analogue
/// of the Layer-1 Pallas kernel) + Cholesky sampling.
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn sample_mvn_side(&self, sweep: &MvnSweep<'_>, latents: &mut Mat, pool: &ThreadPool) {
        let n = latents.rows();
        let k = latents.cols();
        let writer = RowWriter::new(latents);
        pool.parallel_for(n, 1, |i| {
            let mut rng = Rng::for_row(sweep.seed, sweep.iteration, sweep.side_id, i as u64);
            // SAFETY: each i is visited exactly once (threadpool contract)
            let row = unsafe { writer.row_mut(i) };
            sample_one_row_mvn(sweep, i, row, k, &mut rng);
        });
    }
}

thread_local! {
    /// per-thread gather scratch for the rank-4 Gram path (no per-row
    /// allocation on the hot loop — §Perf)
    static GATHER: std::cell::RefCell<(Vec<f64>, Vec<f64>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
    /// per-thread K-sized work area for the solve/sample phase (§Perf
    /// change #3: zero allocations per row)
    static ROW_WORK: std::cell::RefCell<Option<RowWork>> = const { std::cell::RefCell::new(None) };
}

struct RowWork {
    lambda: Mat,
    rhs: Vec<f64>,
    tmp: Vec<f64>,
    eps: Vec<f64>,
    /// Hadamard scratch for tensor design rows
    design: Vec<f64>,
}

impl RowWork {
    fn ensure(slot: &mut Option<RowWork>, k: usize) -> &mut RowWork {
        let fresh = match slot {
            Some(w) => w.rhs.len() != k,
            None => true,
        };
        if fresh {
            *slot = Some(RowWork {
                lambda: Mat::zeros(k, k),
                rhs: vec![0.0; k],
                tmp: vec![0.0; k],
                eps: vec![0.0; k],
                design: Vec::new(),
            });
        }
        slot.as_mut().unwrap()
    }
}

/// The MVN row conditional shared by the native engine and (for the
/// chunked path) the XLA engine's remainder handling:
///   Λ = Λ₀ + Σ_views α O_selᵀ O_sel,   b = Λ₀ μ_i + Σ_views α O_selᵀ r
///   u_i ~ N(Λ⁻¹ b, Λ⁻¹)
pub fn sample_one_row_mvn(
    sweep: &MvnSweep<'_>,
    i: usize,
    row_in_out: &mut [f64],
    k: usize,
    rng: &mut Rng,
) {
    ROW_WORK.with(|w| {
        let mut slot = w.borrow_mut();
        let work = RowWork::ensure(&mut slot, k);
        sample_one_row_mvn_with(sweep, i, row_in_out, k, rng, work);
    });
}

fn sample_one_row_mvn_with(
    sweep: &MvnSweep<'_>,
    i: usize,
    row_in_out: &mut [f64],
    k: usize,
    rng: &mut Rng,
    work: &mut RowWork,
) {
    let RowWork { lambda, rhs, tmp, eps, design } = work;
    lambda.data_mut().copy_from_slice(sweep.lambda0.data());
    let mean_i = sweep.means.row(i);
    // rhs = Λ₀ μ_i (in place)
    for (r, row0) in rhs.iter_mut().zip(0..k) {
        *r = crate::linalg::dot(sweep.lambda0.row(row0), mean_i);
    }
    for view in &sweep.views {
        let alpha = view.alpha;
        match (&view.full_gram, view.probit) {
            (Some(fg), false) => {
                lambda.add_assign(fg);
                view.operand.for_each_design(i, design, |vrow, r| {
                    if r != 0.0 {
                        crate::linalg::axpy(rhs, alpha * r, vrow);
                    }
                });
            }
            _ => {
                // §Perf changes #1+#2: upper-triangle-only accumulation,
                // and (Blocked backend) gather-then-rank-4 so the inner
                // loops are long enough to vectorize; mirrored once
                // below before the Cholesky.
                if crate::linalg::Backend::global() == crate::linalg::Backend::Blocked {
                    GATHER.with(|g| {
                        let (xs, vals) = &mut *g.borrow_mut();
                        xs.clear();
                        vals.clear();
                        view.operand.for_each_design(i, design, |vrow, r| {
                            let val = if view.probit {
                                let pred = crate::linalg::dot(row_in_out, vrow);
                                NoiseModel::augment_probit(pred, r, rng)
                            } else {
                                r
                            };
                            xs.extend_from_slice(vrow);
                            vals.push(val);
                        });
                        crate::linalg::gram_rhs_rank4(lambda, rhs, alpha, xs, vals);
                    });
                } else {
                    view.operand.for_each_design(i, design, |vrow, r| {
                        let val = if view.probit {
                            let pred = crate::linalg::dot(row_in_out, vrow);
                            NoiseModel::augment_probit(pred, r, rng)
                        } else {
                            r
                        };
                        crate::linalg::ger_sym_upper(lambda, alpha, vrow);
                        crate::linalg::axpy(rhs, alpha * val, vrow);
                    });
                }
            }
        }
    }
    crate::linalg::mirror_upper_to_lower(lambda);
    // in-place Cholesky + three triangular solves (no allocation):
    //   mean = Λ⁻¹ rhs,  u = mean + L⁻ᵀ ε
    if crate::linalg::chol_inplace(lambda).is_err() {
        // numerically degenerate row: fall back to the prior mean
        row_in_out.copy_from_slice(mean_i);
        return;
    }
    let l = &*lambda;
    crate::linalg::tri_solve_lower_into(l, rhs, tmp);
    crate::linalg::tri_solve_upper_t_into(l, tmp, rhs); // rhs := mean
    rng.fill_normal(eps);
    crate::linalg::tri_solve_upper_t_into(l, eps, tmp); // tmp := L⁻ᵀε
    for c in 0..k {
        row_in_out[c] = rhs[c] + tmp[c];
    }
}

thread_local! {
    /// per-thread (design rows, values, Hadamard scratch) gather for the
    /// custom-sampler sweep — hoisted out of the hot loop so no `Vec` is
    /// allocated per row (§Perf, same pattern as `GATHER`)
    static CUSTOM_GATHER: std::cell::RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Sweep for priors with custom row conditionals (spike-and-slab).
/// These use a single view (GFA loadings each belong to one view).
pub fn sample_side_custom(
    prior: &dyn Prior,
    view: &ViewSlice<'_>,
    latents: &mut Mat,
    pool: &ThreadPool,
    seed: u64,
    iteration: u64,
    side_id: u64,
) {
    let n = latents.rows();
    sample_side_custom_range(prior, view, latents, pool, seed, iteration, side_id, 0..n);
}

/// [`sample_side_custom`] restricted to `rows` — the shard-block variant
/// used by distributed workers.  Values drawn for a row are identical to
/// the full sweep's (per-row RNG streams).  The observations are handed
/// to the prior as gathered design rows, built in per-thread scratch.
#[allow(clippy::too_many_arguments)]
pub fn sample_side_custom_range(
    prior: &dyn Prior,
    view: &ViewSlice<'_>,
    latents: &mut Mat,
    pool: &ThreadPool,
    seed: u64,
    iteration: u64,
    side_id: u64,
    rows: std::ops::Range<usize>,
) {
    let writer = RowWriter::new(latents);
    let start = rows.start;
    let k = latents.cols();
    pool.parallel_for(rows.len(), 1, |t| {
        let i = start + t;
        let mut rng = Rng::for_row(seed, iteration, side_id, i as u64);
        CUSTOM_GATHER.with(|g| {
            let (designs, vals, scratch) = &mut *g.borrow_mut();
            designs.clear();
            vals.clear();
            view.operand.for_each_design(i, scratch, |vrow, v| {
                designs.extend_from_slice(vrow);
                vals.push(v);
            });
            // SAFETY: disjoint rows
            let row = unsafe { writer.row_mut(i) };
            prior.sample_row_custom(
                i,
                RowObs { designs, vals, k },
                view.alpha,
                &mut rng,
                row,
            );
        });
    });
}

/// Sum of squared residuals over the observed cells of a view — feeds the
/// adaptive-noise Gamma update.  `target` holds the latents of the mode
/// whose fibers `operand` iterates.
pub fn view_sse(operand: &Operand<'_>, target: &Mat, pool: &ThreadPool) -> (f64, usize) {
    let n = target.rows();
    let (sse, cnt) = pool.parallel_map_reduce(
        n,
        8,
        |range| {
            let mut s = 0.0;
            let mut c = 0usize;
            let mut scratch = Vec::new();
            for i in range {
                let trow = target.row(i);
                operand.for_each_design(i, &mut scratch, |vrow, r| {
                    let e = r - crate::linalg::dot(trow, vrow);
                    s += e * e;
                    c += 1;
                });
            }
            (s, c)
        },
        (0.0, 0usize),
        |a, b| (a.0 + b.0, a.1 + b.1),
    );
    (sse, cnt)
}

/// Build the `DataAccess` for a side of a view.
pub fn access_for<'a>(data: &'a MatrixConfig, target_is_rows: bool) -> DataAccess<'a> {
    match (data, target_is_rows) {
        (MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m), true) => {
            DataAccess::SparseRows(m)
        }
        (MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m), false) => {
            DataAccess::SparseCols(m)
        }
        (MatrixConfig::Dense(m), true) => DataAccess::DenseRows(m),
        (MatrixConfig::Dense(m), false) => DataAccess::DenseCols(m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::priors::{NormalPrior, Prior};

    fn toy_problem() -> (crate::sparse::SparseMatrix, Mat) {
        let mut rng = Rng::new(71);
        let (n, m, k) = (40, 30, 4);
        let mut v = Mat::zeros(m, k);
        rng.fill_normal(v.data_mut());
        let mut trips = Vec::new();
        for i in 0..n {
            for j in 0..m {
                if rng.next_f64() < 0.3 {
                    trips.push((i as u32, j as u32, rng.normal()));
                }
            }
        }
        (crate::sparse::SparseMatrix::from_triplets(n, m, trips), v)
    }

    #[test]
    fn native_sweep_is_thread_count_invariant() {
        let (data, v) = toy_problem();
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(72);
        let mut lat = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat, &mut rng);

        let run = |threads: usize, lat0: &Mat| {
            let pool = ThreadPool::new(threads);
            let mut lat = lat0.clone();
            let spec = prior.mvn_spec().unwrap();
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: spec.means,
                views: vec![ViewSlice::matrix(
                    DataAccess::SparseRows(&data),
                    &v,
                    2.0,
                    false,
                    None,
                )],
                seed: 7,
                iteration: 3,
                side_id: 0,
            };
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(1, &lat);
        let b = run(4, &lat);
        let c = run(7, &lat);
        assert!(a.max_abs_diff(&b) == 0.0, "1 vs 4 threads must be identical");
        assert!(b.max_abs_diff(&c) == 0.0);
        lat = a; // silence unused warning chain
        assert!(lat.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn range_sweep_matches_full_sweep_on_owned_rows() {
        // sampling two disjoint shards must reproduce the full sweep
        // bit-exactly (the determinism invariant distributed training
        // relies on)
        let (data, v) = toy_problem();
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(74);
        let lat0 = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let pool = ThreadPool::new(3);
        let spec = prior.mvn_spec().unwrap();
        let make_sweep = || MvnSweep {
            lambda0: spec.lambda0,
            means: MeanSpec::Shared(match &spec.means {
                MeanSpec::Shared(s) => *s,
                _ => unreachable!(),
            }),
            views: vec![ViewSlice::matrix(
                DataAccess::SparseRows(&data),
                &v,
                2.0,
                false,
                None,
            )],
            seed: 9,
            iteration: 5,
            side_id: 0,
        };
        let mut full = lat0.clone();
        NativeEngine.sample_mvn_side(&make_sweep(), &mut full, &pool);
        let mut sharded = lat0.clone();
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 0..17);
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 17..40);
        assert_eq!(full.max_abs_diff(&sharded), 0.0, "shard sweeps must equal full sweep");
        // empty range is a no-op
        let before = sharded.clone();
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 7..7);
        assert_eq!(before.max_abs_diff(&sharded), 0.0);
    }

    #[test]
    fn full_gram_path_matches_explicit_dense_iteration() {
        // fully-observed dense data: fast path (full_gram) must equal the
        // naive per-entry accumulation
        let mut rng = Rng::new(73);
        let (n, m, k) = (10, 8, 3);
        let mut dense = Mat::zeros(n, m);
        rng.fill_normal(dense.data_mut());
        let mut v = Mat::zeros(m, k);
        rng.fill_normal(v.data_mut());
        let mut prior = NormalPrior::new(k);
        let mut lat = crate::model::init_latents(n, k, 0.1, &mut rng);
        prior.update_hyper(&lat, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let pool = ThreadPool::new(2);

        let alpha = 1.5;
        let make_sweep = |full: bool| MvnSweep {
            lambda0: spec.lambda0,
            means: MeanSpec::Shared(match &spec.means {
                MeanSpec::Shared(s) => *s,
                _ => unreachable!(),
            }),
            views: vec![ViewSlice::matrix(
                DataAccess::DenseRows(&dense),
                &v,
                alpha,
                false,
                full.then(|| ViewSlice::full_gram_for(&v, alpha)),
            )],
            seed: 11,
            iteration: 0,
            side_id: 0,
        };
        let mut lat_fast = lat.clone();
        NativeEngine.sample_mvn_side(&make_sweep(true), &mut lat_fast, &pool);
        let mut lat_slow = lat.clone();
        NativeEngine.sample_mvn_side(&make_sweep(false), &mut lat_slow, &pool);
        // same RNG streams, same math -> tiny float drift from accumulation order
        assert!(lat_fast.max_abs_diff(&lat_slow) < 1e-6);
        lat = lat_fast;
        assert!(lat.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn view_sse_counts_and_sums() {
        let (data, v) = toy_problem();
        let lat = Mat::zeros(40, 4); // all-zero latents -> residual = r
        let pool = ThreadPool::new(3);
        let op = Operand::Matrix { data: DataAccess::SparseRows(&data), other: &v };
        let (sse, cnt) = view_sse(&op, &lat, &pool);
        let want: f64 = data.triplets().map(|(_, _, r)| r * r).sum();
        assert!((sse - want).abs() < 1e-9);
        assert_eq!(cnt, data.nnz());
    }

    #[test]
    fn two_mode_tensor_operand_is_bit_identical_to_matrix_operand() {
        // the enabling invariant of the N-mode refactor: a 2-mode tensor
        // slice must replay the matrix slice exactly — same design rows
        // in the same order, same RNG streams, zero float drift
        let (data, v) = toy_problem();
        let tensor = crate::sparse::SparseTensor::from_matrix(&data);
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(75);
        let lat0 = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let pool = ThreadPool::new(3);
        let shared = match &spec.means {
            MeanSpec::Shared(s) => *s,
            _ => unreachable!(),
        };
        let run = |slice: ViewSlice<'_>| {
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: MeanSpec::Shared(shared),
                views: vec![slice],
                seed: 13,
                iteration: 2,
                side_id: 0,
            };
            let mut lat = lat0.clone();
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(ViewSlice::matrix(DataAccess::SparseRows(&data), &v, 2.0, false, None));
        let b = run(ViewSlice::tensor_mode(&tensor, 0, vec![(1, &v)], 2.0, false));
        assert_eq!(a.max_abs_diff(&b), 0.0, "2-mode tensor sweep must equal matrix sweep");
        // and the SSE path agrees bit-for-bit too
        let mop = Operand::Matrix { data: DataAccess::SparseRows(&data), other: &v };
        let top = Operand::TensorMode(TensorModeOperand {
            tensor: &tensor,
            mode: 0,
            others: vec![(1, &v)],
        });
        let (s1, c1) = view_sse(&mop, &a, &pool);
        let (s2, c2) = view_sse(&top, &a, &pool);
        assert_eq!(s1, s2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn three_mode_sweep_is_thread_invariant_and_finite() {
        let mut rng = Rng::new(77);
        let (n0, n1, n2, k) = (20, 15, 10, 3);
        let mut f1 = Mat::zeros(n1, k);
        let mut f2 = Mat::zeros(n2, k);
        rng.fill_normal(f1.data_mut());
        rng.fill_normal(f2.data_mut());
        let mut entries = Vec::new();
        for i in 0..n0 {
            for j in 0..n1 {
                for l in 0..n2 {
                    if rng.next_f64() < 0.1 {
                        entries.push((vec![i as u32, j as u32, l as u32], rng.normal()));
                    }
                }
            }
        }
        let tensor = crate::sparse::SparseTensor::from_entries(vec![n0, n1, n2], entries);
        let mut prior = NormalPrior::new(k);
        let lat0 = crate::model::init_latents(n0, k, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let shared = match &spec.means {
            MeanSpec::Shared(s) => *s,
            _ => unreachable!(),
        };
        let run = |threads: usize| {
            let pool = ThreadPool::new(threads);
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: MeanSpec::Shared(shared),
                views: vec![ViewSlice::tensor_mode(
                    &tensor,
                    0,
                    vec![(1, &f1), (2, &f2)],
                    1.5,
                    false,
                )],
                seed: 17,
                iteration: 4,
                side_id: 0,
            };
            let mut lat = lat0.clone();
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(1);
        let b = run(5);
        assert_eq!(a.max_abs_diff(&b), 0.0, "3-mode sweep must be schedule-invariant");
        assert!(a.data().iter().all(|x| x.is_finite()));
        // design rows really are Hadamard products: check nnz bookkeeping
        let op = Operand::TensorMode(TensorModeOperand {
            tensor: &tensor,
            mode: 0,
            others: vec![(1, &f1), (2, &f2)],
        });
        let mut seen = 0;
        let mut scratch = Vec::new();
        op.for_each_design(0, &mut scratch, |vrow, _| {
            assert_eq!(vrow.len(), k);
            seen += 1;
        });
        assert_eq!(seen, tensor.mode_nnz(0, 0));
        assert_eq!(op.k(), k);
    }

    #[test]
    fn access_for_orientation() {
        let (data, _) = toy_problem();
        let mc = MatrixConfig::SparseUnknown(data.clone());
        assert_eq!(access_for(&mc, true).nnz(0), data.row_nnz(0));
        assert_eq!(access_for(&mc, false).nnz(0), data.col_nnz(0));
        let d = MatrixConfig::Dense(Mat::zeros(3, 5));
        assert_eq!(access_for(&d, true).nnz(2), 5);
        assert_eq!(access_for(&d, false).nnz(4), 3);
    }

    #[test]
    fn dense_cols_access_reads_columns() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let acc = DataAccess::DenseCols(&m);
        let mut got = Vec::new();
        acc.for_each_obs(1, |j, v| got.push((j, v)));
        assert_eq!(got, vec![(0, 2.0), (1, 5.0)]);
    }
}
