//! The coordination layer (Layer 3): the parallel Gibbs sweep over one
//! *mode* of the model (a matrix's rows or columns, or mode m of an
//! N-mode tensor view), the engine abstraction that lets the same sweep
//! run on the native Rust kernels or on the AOT-compiled XLA artifacts,
//! and the fork-join [`ThreadPool`] standing in for OpenMP.
//!
//! The MVN row conditional never sees matrices vs tensors: per observed
//! entry it consumes a *design row* through [`Operand`] — the opposite
//! side's latent row for matrices, the Hadamard product of the other
//! modes' latent rows (built in per-thread scratch, no per-row
//! allocation) for tensors — so [`sample_one_row_mvn`], the engines and
//! [`view_sse`] are shared by both paths rather than forked.
//!
//! Determinism invariant (DESIGN.md §5, property-tested in
//! `rust/tests/coordinator_props.rs`): every row i of iteration t draws
//! from `Rng::for_row(seed, t, side, i)`, so the sampled latents are
//! identical for any thread count and any schedule.
//!
//! §Perf PR4 — the sweep runs through a per-sweep [`SweepPlan`]: the
//! shared `Λ₀·μ` rhs base is hoisted out of the row loop, rows are
//! issued in descending-nnz (LPT) order, every pool lane gets a
//! preallocated work arena instead of per-row `thread_local` borrows,
//! high-nnz rows accumulate Λ through the cache-blocked
//! [`gram_rhs_tile`](crate::linalg::gram_rhs_tile) kernel
//! (bit-identical to the rank-4 path, so the [`TILE_NNZ_MIN`] threshold
//! never changes results), and the adaptive-noise SSE pass can be fused
//! into the sweep ([`Engine::sample_mvn_side_fused`]), bit-identical to
//! the standalone [`view_sse`].  [`SweepTuning`] switches each
//! optimisation for the `smurff bench sweep` baseline comparison.

pub mod threadpool;

pub use threadpool::ThreadPool;

use crate::data::MatrixConfig;
use crate::linalg::Mat;
use crate::noise::NoiseModel;
use crate::priors::{MeanSpec, Prior, RowObs};
use crate::rng::Rng;
use crate::sparse::SparseTensor;
use std::sync::atomic::{AtomicU8, Ordering};

/// Rows with at least this many observations take the cache-blocked
/// tiled Gram path; shorter rows keep the single rank-4 gather (the
/// tile bookkeeping would outweigh the cache win).  Either path gives
/// bit-identical results (see [`crate::linalg::gram_rhs_tile`]), so the
/// threshold is purely a performance knob.
pub const TILE_NNZ_MIN: usize = 2 * crate::linalg::GRAM_TILE_ROWS;

/// Switches for the §Perf PR4 sweep optimisations — all on by default.
/// Sessions snapshot a value at build time (overridable per session via
/// `SessionBuilder::sweep_tuning`, which is how `smurff bench sweep`
/// measures the unoptimised baseline) and stamp it onto every
/// [`MvnSweep`] they run.  Every switch is *sample-preserving*: the
/// tiled Gram path is bit-identical to the rank-4 path, the hoisted rhs
/// base is a bit-identical copy of the per-row dots, and the LPT order
/// only changes scheduling (per-row RNG streams make samples
/// schedule-invariant).  `fused_sse` changes which operand orientation
/// the adaptive-noise SSE is summed over (the final mode instead of
/// mode 0) — a float-summation-order difference in the noise update
/// only, never in the sampled latents of a sweep.
///
/// `backend` (ISSUE 8) is the one exception to "sample-preserving": it
/// selects the kernel ISA family ([`crate::linalg::Backend`]) for the
/// sweep's solve path.  `Blocked`/`Naive` stay in the seed-identical
/// scalar family; `Simd` is tolerance-equivalent (see
/// [`crate::linalg::simd`]) and is masked back to `Blocked` while
/// strict mode is on.  It rides this struct so the existing snapshot
/// seam (per-session at build, replicated verbatim to every distributed
/// worker) pins the ISA uniformly across threads and ranks — which is
/// what keeps the distributed `sync` cross-rank hash assert green under
/// SIMD.  It is *not* part of the four-switch global bitmask:
/// [`SweepTuning::set_global`] stores only the switches, and every
/// constructor reads the backend from [`crate::linalg::Backend::global`]
/// at call time, so `all_on()`/`baseline()` comparisons are always
/// ISA-uniform.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepTuning {
    /// cache-blocked tiled Gram for rows with ≥ [`TILE_NNZ_MIN`] obs
    pub tiled_gram: bool,
    /// fuse the adaptive-noise SSE pass into the final mode's sweep
    pub fused_sse: bool,
    /// issue rows in descending-nnz (LPT) order
    pub lpt_schedule: bool,
    /// hoist the shared Λ₀·μ rhs base out of the row loop
    pub hoist_rhs: bool,
    /// kernel ISA family for the MVN solve path (see struct docs)
    pub backend: crate::linalg::Backend,
}

static SWEEP_TUNING: AtomicU8 = AtomicU8::new(0b1111);

impl SweepTuning {
    /// Every optimisation enabled (the library default), on the
    /// process-default kernel backend.
    pub fn all_on() -> SweepTuning {
        SweepTuning {
            tiled_gram: true,
            fused_sse: true,
            lpt_schedule: true,
            hoist_rhs: true,
            backend: crate::linalg::Backend::global(),
        }
    }

    /// The pre-PR4 baseline: rank-4 gather only, standalone SSE pass,
    /// natural row order, per-row rhs dots.  Same backend as
    /// [`SweepTuning::all_on`], so switch comparisons never cross ISA.
    pub fn baseline() -> SweepTuning {
        SweepTuning {
            tiled_gram: false,
            fused_sse: false,
            lpt_schedule: false,
            hoist_rhs: false,
            backend: crate::linalg::Backend::global(),
        }
    }

    /// This tuning with the kernel backend replaced — the builder-side
    /// hook for `--engine native:scalar` / `native:simd`.
    pub fn with_backend(self, backend: crate::linalg::Backend) -> SweepTuning {
        SweepTuning { backend: backend.sanitized(), ..self }
    }

    /// Set the process-wide *default* switches.  The global is only
    /// consulted when a session is built without an explicit
    /// `SessionBuilder::sweep_tuning` override — the hot path reads the
    /// sweep's own [`MvnSweep::tuning`] snapshot, never this global —
    /// so code that needs a specific tuning for one session (tests,
    /// the bench harness) should pin it on the builder instead of
    /// flipping this around a build.  The `backend` field is *not*
    /// stored here; its process-wide default is
    /// [`crate::linalg::Backend::set_global`].
    pub fn set_global(t: SweepTuning) {
        let bits = t.tiled_gram as u8
            | (t.fused_sse as u8) << 1
            | (t.lpt_schedule as u8) << 2
            | (t.hoist_rhs as u8) << 3;
        SWEEP_TUNING.store(bits, Ordering::Relaxed);
    }

    pub fn global() -> SweepTuning {
        let b = SWEEP_TUNING.load(Ordering::Relaxed);
        SweepTuning {
            tiled_gram: b & 1 != 0,
            fused_sse: b & 2 != 0,
            lpt_schedule: b & 4 != 0,
            hoist_rhs: b & 8 != 0,
            backend: crate::linalg::Backend::global(),
        }
    }
}

/// How the rows of the side being updated see one data view.
pub enum DataAccess<'a> {
    /// target rows are matrix rows (CSR view)
    SparseRows(&'a crate::sparse::SparseMatrix),
    /// target rows are matrix columns (CSC view)
    SparseCols(&'a crate::sparse::SparseMatrix),
    /// dense data, target rows are matrix rows
    DenseRows(&'a Mat),
    /// dense data, target rows are matrix columns
    DenseCols(&'a Mat),
}

impl<'a> DataAccess<'a> {
    /// Number of observed entries for target row i.
    pub fn nnz(&self, i: usize) -> usize {
        match self {
            DataAccess::SparseRows(m) => m.row_nnz(i),
            DataAccess::SparseCols(m) => m.col_nnz(i),
            DataAccess::DenseRows(m) => m.cols(),
            DataAccess::DenseCols(m) => m.rows(),
        }
    }

    /// Visit every observed (other_index, value) of target row i.
    #[inline]
    pub fn for_each_obs<F: FnMut(usize, f64)>(&self, i: usize, mut f: F) {
        match self {
            DataAccess::SparseRows(m) => {
                let (idx, vals) = m.row(i);
                for (&j, &v) in idx.iter().zip(vals) {
                    f(j as usize, v);
                }
            }
            DataAccess::SparseCols(m) => {
                let (idx, vals) = m.col(i);
                for (&j, &v) in idx.iter().zip(vals) {
                    f(j as usize, v);
                }
            }
            DataAccess::DenseRows(m) => {
                for (j, &v) in m.row(i).iter().enumerate() {
                    f(j, v);
                }
            }
            DataAccess::DenseCols(m) => {
                for j in 0..m.rows() {
                    f(j, m[(j, i)]);
                }
            }
        }
    }

    /// Gather (idx, vals) into scratch vectors (used by custom samplers
    /// and the XLA engine's block marshalling).
    pub fn gather(&self, i: usize, idx: &mut Vec<u32>, vals: &mut Vec<f64>) {
        idx.clear();
        vals.clear();
        self.for_each_obs(i, |j, v| {
            idx.push(j as u32);
            vals.push(v);
        });
    }
}

/// Mode m of a tensor view as seen from the sweep updating that mode:
/// the design row of an observation is the Hadamard product of the
/// *other* modes' latent rows at the observation's coordinates.
pub struct TensorModeOperand<'a> {
    pub tensor: &'a SparseTensor,
    /// the mode being updated
    pub mode: usize,
    /// (mode id, factor matrix) for every mode except `mode`, ascending
    pub others: Vec<(usize, &'a Mat)>,
}

/// How the target rows of the mode being updated see one data view: per
/// observed entry the MVN conditional consumes a *design row*.
pub enum Operand<'a> {
    /// 2-mode case — design row = `other.row(j)` for observation (i, j)
    Matrix {
        data: DataAccess<'a>,
        /// the opposite side's latents
        other: &'a Mat,
    },
    /// N-mode case — design rows built per observation in caller scratch
    TensorMode(TensorModeOperand<'a>),
}

impl<'a> Operand<'a> {
    /// Number of observed entries for target index i.
    pub fn nnz(&self, i: usize) -> usize {
        match self {
            Operand::Matrix { data, .. } => data.nnz(i),
            Operand::TensorMode(t) => t.tensor.mode_nnz(t.mode, i),
        }
    }

    /// Latent dimension K of the design rows.
    pub fn k(&self) -> usize {
        match self {
            Operand::Matrix { other, .. } => other.cols(),
            Operand::TensorMode(t) => t.others[0].1.cols(),
        }
    }

    /// Visit every observation of target index i as (design row, value).
    /// `scratch` backs the ≥3-mode Hadamard products; matrices and
    /// 2-mode tensors hand out factor rows directly without copying, so
    /// the 2-mode tensor path is bit-identical to the matrix path.
    #[inline]
    pub fn for_each_design<F: FnMut(&[f64], f64)>(
        &self,
        i: usize,
        scratch: &mut Vec<f64>,
        mut f: F,
    ) {
        match self {
            Operand::Matrix { data, other } => {
                data.for_each_obs(i, |j, v| f(other.row(j), v));
            }
            Operand::TensorMode(t) => {
                let fiber = t.tensor.mode_fiber(t.mode, i);
                if t.others.len() == 1 {
                    // exactly one other mode: its latent row IS the design
                    let (om, fac) = t.others[0];
                    for &e in fiber {
                        let e = e as usize;
                        f(fac.row(t.tensor.coord(om, e) as usize), t.tensor.val(e));
                    }
                    return;
                }
                let k = self.k();
                scratch.resize(k, 0.0);
                let (&(m0, f0), rest) = t.others.split_first().expect("≥2 other modes");
                for &e in fiber {
                    let e = e as usize;
                    scratch.copy_from_slice(f0.row(t.tensor.coord(m0, e) as usize));
                    for &(m, fac) in rest {
                        let frow = fac.row(t.tensor.coord(m, e) as usize);
                        for (s, &x) in scratch.iter_mut().zip(frow) {
                            *s *= x;
                        }
                    }
                    f(&scratch[..], t.tensor.val(e));
                }
            }
        }
    }

    /// The matrix parts (data access + opposite-side latents) when this
    /// is the 2-mode operand — the XLA engine's fast-path gate.
    pub fn matrix_parts(&self) -> Option<(&DataAccess<'a>, &'a Mat)> {
        match self {
            Operand::Matrix { data, other } => Some((data, *other)),
            Operand::TensorMode(_) => None,
        }
    }
}

/// One data view as seen from the mode being updated.
pub struct ViewSlice<'a> {
    pub operand: Operand<'a>,
    /// likelihood precision of this view
    pub alpha: f64,
    /// probit augmentation (binary data)?
    pub probit: bool,
    /// α · OᵀO precomputed when the view is fully observed (the
    /// "sparse fully known" / "dense" fast path of Table 1)
    pub full_gram: Option<Mat>,
}

impl<'a> ViewSlice<'a> {
    /// The 2-mode slice: target rows see `data`, design rows come from
    /// the opposite side's latents `other`.
    pub fn matrix(
        data: DataAccess<'a>,
        other: &'a Mat,
        alpha: f64,
        probit: bool,
        full_gram: Option<Mat>,
    ) -> ViewSlice<'a> {
        ViewSlice { operand: Operand::Matrix { data, other }, alpha, probit, full_gram }
    }

    /// Mode `mode` of an N-mode tensor view; `others` pairs every other
    /// mode id with its factor matrix, ascending.
    pub fn tensor_mode(
        tensor: &'a SparseTensor,
        mode: usize,
        others: Vec<(usize, &'a Mat)>,
        alpha: f64,
        probit: bool,
    ) -> ViewSlice<'a> {
        assert_eq!(
            others.len() + 1,
            tensor.nmodes(),
            "tensor slice needs one factor per other mode"
        );
        ViewSlice {
            operand: Operand::TensorMode(TensorModeOperand { tensor, mode, others }),
            alpha,
            probit,
            full_gram: None,
        }
    }

    /// Precompute the full-gram fast path for fully-observed data.
    pub fn full_gram_for(other: &Mat, alpha: f64) -> Mat {
        let mut g = crate::linalg::syrk(other, crate::linalg::Backend::global());
        g.scale(alpha);
        g
    }
}

/// Everything an engine needs to resample one side with MVN conditionals.
pub struct MvnSweep<'a> {
    pub lambda0: &'a Mat,
    pub means: MeanSpec<'a>,
    pub views: Vec<ViewSlice<'a>>,
    pub seed: u64,
    pub iteration: u64,
    /// 0 = rows side, 1.. = column side of view v-1
    pub side_id: u64,
    /// §Perf switches for this sweep — sessions pass their build-time
    /// snapshot, so the engine never reads the process global on the
    /// hot path (the per-session pin is authoritative).  All switches
    /// are sample-preserving; `fused_sse` is inert here (the fuse
    /// decision arrives as the explicit `fuse_sse` argument).
    pub tuning: SweepTuning,
}

/// A sampling engine: resamples all rows of `latents` in place.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;
    fn sample_mvn_side(&self, sweep: &MvnSweep<'_>, latents: &mut Mat, pool: &ThreadPool);

    /// Resample only `rows` (a distributed shard's block).  Must draw
    /// exactly the values `sample_mvn_side` would draw for those rows —
    /// guaranteed here because every row i uses `Rng::for_row(seed, t,
    /// side, i)` regardless of which node (or engine) samples it.  The
    /// full range delegates to `sample_mvn_side` so engine fast paths
    /// (XLA blocking) still apply to single-node sweeps.
    fn sample_mvn_side_range(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
    ) {
        if rows.start == 0 && rows.end == latents.rows() {
            return self.sample_mvn_side(sweep, latents, pool);
        }
        let k = latents.cols();
        let writer = RowWriter::new(latents);
        let start = rows.start;
        pool.parallel_for(rows.len(), 1, |t| {
            let i = start + t;
            let mut rng = Rng::for_row(sweep.seed, sweep.iteration, sweep.side_id, i as u64);
            // SAFETY: each i is visited exactly once (threadpool contract)
            let row = unsafe { writer.row_mut(i) };
            sample_one_row_mvn(sweep, i, row, k, &mut rng);
        });
    }

    /// [`sample_mvn_side_range`](Engine::sample_mvn_side_range) that can
    /// additionally *fuse* the adaptive-noise SSE pass into the sweep:
    /// with `fuse_sse` set (the sweep must then carry exactly one view),
    /// returns that view's sum of squared residuals and observation
    /// count over `rows`, computed against the freshly sampled rows.
    /// Over the full range this is bit-identical to calling
    /// [`view_sse`] on the same operand and target afterwards (a shard
    /// range folds only its own rows; callers combine shard sums
    /// themselves).  Engines without a fused path sample and return
    /// `None`; callers fall back to the standalone pass.
    fn sample_mvn_side_fused(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
        fuse_sse: bool,
    ) -> Option<(f64, usize)> {
        let _ = fuse_sse;
        self.sample_mvn_side_range(sweep, latents, pool, rows);
        None
    }
}

/// Shared mutable row access for disjoint parallel row writes.
pub struct RowWriter {
    ptr: *mut f64,
    cols: usize,
    #[allow(dead_code)]
    rows: usize,
}

unsafe impl Send for RowWriter {}
unsafe impl Sync for RowWriter {}

impl RowWriter {
    pub fn new(m: &mut Mat) -> RowWriter {
        RowWriter { ptr: m.data_mut().as_mut_ptr(), cols: m.cols(), rows: m.rows() }
    }

    /// # Safety
    /// Each row index must be accessed by at most one thread at a time.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn row_mut(&self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        std::slice::from_raw_parts_mut(self.ptr.add(i * self.cols), self.cols)
    }
}

/// The per-sweep execution plan (§Perf PR4): everything the row loop
/// used to recompute (or `thread_local`-borrow) per row, computed once
/// per sweep — the hoisted shared-rhs base, the LPT visit order and one
/// preallocated work area per pool lane.
pub struct SweepPlan {
    /// visit order over the sweep's *local* indices (descending total
    /// nnz, ties by index) — `None` = natural order (uniform weights)
    order: Option<Vec<u32>>,
    /// hoisted Λ₀·μ when means are shared: K dot products once per
    /// sweep instead of once per row; the per-row copy is bit-identical
    /// to recomputing the dots
    rhs_base: Option<Vec<f64>>,
    /// one work area per pool lane — replaces per-row `thread_local`
    /// `RefCell` borrows
    arena: LaneArena,
    tuning: SweepTuning,
}

impl SweepPlan {
    pub fn build(
        sweep: &MvnSweep<'_>,
        rows: &std::ops::Range<usize>,
        k: usize,
        nlanes: usize,
    ) -> SweepPlan {
        let tuning = sweep.tuning;
        let rhs_base = match (&sweep.means, tuning.hoist_rhs) {
            (MeanSpec::Shared(mu), true) => {
                let mut base = vec![0.0; k];
                for (r, row0) in base.iter_mut().zip(0..k) {
                    *r = crate::linalg::dot(sweep.lambda0.row(row0), mu);
                }
                Some(base)
            }
            _ => None,
        };
        let order = if tuning.lpt_schedule { lpt_order(sweep, rows) } else { None };
        SweepPlan { order, rhs_base, arena: LaneArena::new(nlanes, k), tuning }
    }

    /// The LPT visit order, if the row weights warranted one.
    pub fn order(&self) -> Option<&[u32]> {
        self.order.as_deref()
    }

    /// The hoisted shared-rhs base, if means are shared and hoisting on.
    pub fn rhs_base(&self) -> Option<&[f64]> {
        self.rhs_base.as_deref()
    }

    /// Fold the per-lane sweep statistics into the global [`crate::obs`]
    /// registry — called once per sweep, after the pool has joined, so
    /// reading the arena is single-threaded.  Gated on `obs::enabled()`;
    /// the arena is fresh per sweep, so stats never double-count.
    fn fold_obs(&self) {
        if !crate::obs::enabled() {
            return;
        }
        let (mut rows, mut tiled, mut rank4, mut degen, mut fused) = (0u64, 0u64, 0u64, 0u64, 0u64);
        let mut rows_simd = 0u64;
        for l in 0..self.arena.lanes.len() {
            // SAFETY: the sweep's pool call has returned — no thread
            // holds a lane any more.
            let s = &unsafe { self.arena.lane(l) }.stats;
            rows += s.rows;
            rows_simd += s.rows_simd;
            tiled += s.gram_tiled;
            rank4 += s.gram_rank4;
            degen += s.chol_degenerate;
            fused += s.sse_fused;
            if s.rows > 0 {
                crate::obs::counter_add(
                    &format!("smurff_sweep_lane_rows_total{{lane=\"{l}\"}}"),
                    s.rows,
                );
            }
        }
        crate::obs::counter_add("smurff_sweep_rows_total", rows);
        crate::obs::counter_add("smurff_sweep_rows_simd_total", rows_simd);
        crate::obs::counter_add("smurff_sweep_gram_tiled_total", tiled);
        crate::obs::counter_add("smurff_sweep_gram_rank4_total", rank4);
        crate::obs::counter_add("smurff_sweep_chol_degenerate_total", degen);
        crate::obs::counter_add("smurff_sweep_sse_fused_rows_total", fused);
    }
}

/// Descending-nnz (LPT-style) permutation of the sweep's local row
/// indices, or `None` when the weights are uniform (dense and
/// fully-observed views) and ordering would buy nothing.  Deterministic:
/// descending total nnz across views, ascending index on ties.
fn lpt_order(sweep: &MvnSweep<'_>, rows: &std::ops::Range<usize>) -> Option<Vec<u32>> {
    let n = rows.len();
    if n < 2 || n > u32::MAX as usize {
        return None;
    }
    let start = rows.start;
    let weights: Vec<usize> = (0..n)
        .map(|t| sweep.views.iter().map(|v| v.operand.nnz(start + t)).sum())
        .collect();
    let (lo, hi) = weights.iter().fold((usize::MAX, 0), |(l, h), &w| (l.min(w), h.max(w)));
    if lo == hi {
        return None;
    }
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        weights[b as usize].cmp(&weights[a as usize]).then(a.cmp(&b))
    });
    Some(order)
}

/// One preallocated work area per pool lane.
struct LaneArena {
    lanes: Vec<std::cell::UnsafeCell<RowWork>>,
}

// SAFETY: the ThreadPool lane contract — each lane id is held by exactly
// one OS thread at a time and a lane's invocations are sequential — so
// distinct threads never alias one lane's RowWork.
unsafe impl Sync for LaneArena {}

impl LaneArena {
    fn new(nlanes: usize, k: usize) -> LaneArena {
        LaneArena {
            lanes: (0..nlanes.max(1)).map(|_| std::cell::UnsafeCell::new(RowWork::new(k))).collect(),
        }
    }

    /// # Safety
    /// `lane` must obey the pool's exclusivity contract (one thread per
    /// lane at a time).
    #[allow(clippy::mut_from_ref)]
    unsafe fn lane(&self, l: usize) -> &mut RowWork {
        &mut *self.lanes[l].get()
    }
}

/// Disjoint-slot writer for the fused-SSE per-row partials (same
/// pattern as `RowWriter` / `parallel_collect`).
struct SsePtr(*mut f64);
unsafe impl Send for SsePtr {}
unsafe impl Sync for SsePtr {}

/// The pure-Rust engine: per-row Gram accumulation (the native analogue
/// of the Layer-1 Pallas kernel) + Cholesky sampling, run through a
/// per-sweep [`SweepPlan`].
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn sample_mvn_side(&self, sweep: &MvnSweep<'_>, latents: &mut Mat, pool: &ThreadPool) {
        let n = latents.rows();
        self.planned_sweep(sweep, latents, pool, 0..n, false);
    }

    fn sample_mvn_side_range(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
    ) {
        self.planned_sweep(sweep, latents, pool, rows, false);
    }

    fn sample_mvn_side_fused(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
        fuse_sse: bool,
    ) -> Option<(f64, usize)> {
        self.planned_sweep(sweep, latents, pool, rows, fuse_sse)
    }
}

impl NativeEngine {
    /// The planned sweep (§Perf PR4): build a [`SweepPlan`] once, then
    /// sample `rows` through it — LPT issue order, per-lane arenas, the
    /// hoisted rhs base — optionally writing per-row SSE partials that
    /// are folded in row order after the join (bit-identical to
    /// [`view_sse`] over the same operand and the fresh latents).
    fn planned_sweep(
        &self,
        sweep: &MvnSweep<'_>,
        latents: &mut Mat,
        pool: &ThreadPool,
        rows: std::ops::Range<usize>,
        fuse_sse: bool,
    ) -> Option<(f64, usize)> {
        let k = latents.cols();
        let n = rows.len();
        let start = rows.start;
        if fuse_sse {
            assert_eq!(sweep.views.len(), 1, "fused SSE needs a single-view sweep");
        }
        if n == 0 {
            return fuse_sse.then_some((0.0, 0));
        }
        let _sweep_span = crate::obs::span_dyn("sweep", || {
            format!("sweep side{} rows{}", sweep.side_id, n)
        });
        let sweep_timer = crate::util::Timer::start();
        let plan = SweepPlan::build(sweep, &rows, k, pool.nthreads());
        let writer = RowWriter::new(latents);
        let mut sse_rows: Vec<f64> = vec![0.0; if fuse_sse { n } else { 0 }];
        let sse_ptr = SsePtr(sse_rows.as_mut_ptr());
        let plan_ref = &plan;
        pool.parallel_for_lane(n, 1, plan.order(), |lane, t| {
            let i = start + t;
            let mut rng = Rng::for_row(sweep.seed, sweep.iteration, sweep.side_id, i as u64);
            // SAFETY: each t is visited exactly once (threadpool contract)
            let row = unsafe { writer.row_mut(i) };
            // SAFETY: lane exclusivity (threadpool contract)
            let work = unsafe { plan_ref.arena.lane(lane) };
            let sse = sample_one_row_mvn_with(
                sweep,
                i,
                row,
                k,
                &mut rng,
                work,
                plan_ref.rhs_base(),
                plan_ref.tuning,
                fuse_sse,
            );
            if fuse_sse {
                // SAFETY: disjoint slots; the Vec outlives the blocking call
                unsafe { *sse_ptr.0.add(t) = sse };
            }
        });
        plan.fold_obs();
        if crate::obs::enabled() {
            crate::obs::histogram("smurff_sweep_seconds", crate::obs::LATENCY_BOUNDS_S)
                .observe(sweep_timer.elapsed_s());
        }
        fuse_sse.then(|| {
            // fold per-row partials with view_sse's chunk grouping so
            // the two are bit-identical
            let sse = fold_sse_rows(&sse_rows);
            let op = &sweep.views[0].operand;
            let cnt: usize = (start..start + n).map(|i| op.nnz(i)).sum();
            (sse, cnt)
        })
    }
}

thread_local! {
    /// per-thread work area for engine-external callers of
    /// [`sample_one_row_mvn`] (the XLA engine's heavy-row remainder,
    /// baselines); the native engine itself uses the [`SweepPlan`]
    /// lane arena instead
    static ROW_WORK: std::cell::RefCell<Option<RowWork>> = const { std::cell::RefCell::new(None) };
}

/// Plain per-lane sweep statistics (ISSUE 6).  Not atomic on purpose:
/// lane exclusivity already guarantees single-writer, so these cost one
/// register increment per row; [`SweepPlan::fold_obs`] folds them into
/// the global registry once per sweep, after the pool has joined.  The
/// increments are unconditional and touch no RNG, so the sampled chain
/// is bit-identical with or without observability.
#[derive(Default)]
struct LaneStats {
    rows: u64,
    rows_simd: u64,
    gram_tiled: u64,
    gram_rank4: u64,
    chol_degenerate: u64,
    sse_fused: u64,
}

struct RowWork {
    lambda: Mat,
    rhs: Vec<f64>,
    tmp: Vec<f64>,
    eps: Vec<f64>,
    /// Hadamard scratch for tensor design rows
    design: Vec<f64>,
    /// gathered design rows: the whole row for the rank-4 path, one
    /// bounded tile for the tiled path
    xs: Vec<f64>,
    /// gathered (probit: augmented) observation values
    vals: Vec<f64>,
    stats: LaneStats,
}

impl RowWork {
    fn new(k: usize) -> RowWork {
        RowWork {
            lambda: Mat::zeros(k, k),
            rhs: vec![0.0; k],
            tmp: vec![0.0; k],
            eps: vec![0.0; k],
            design: Vec::new(),
            xs: Vec::new(),
            vals: Vec::new(),
            stats: LaneStats::default(),
        }
    }

    fn ensure(slot: &mut Option<RowWork>, k: usize) -> &mut RowWork {
        let fresh = match slot {
            Some(w) => w.rhs.len() != k,
            None => true,
        };
        if fresh {
            *slot = Some(RowWork::new(k));
        }
        slot.as_mut().unwrap()
    }
}

/// The MVN row conditional shared by the native engine and (for the
/// chunked path) the XLA engine's remainder handling:
///   Λ = Λ₀ + Σ_views α O_selᵀ O_sel,   b = Λ₀ μ_i + Σ_views α O_selᵀ r
///   u_i ~ N(Λ⁻¹ b, Λ⁻¹)
/// Bit-identical to the [`SweepPlan`] path (same kernels, same
/// threshold, and the hoisted rhs base is a copy of the dots computed
/// here), so engine fallbacks never perturb the chain.
pub fn sample_one_row_mvn(
    sweep: &MvnSweep<'_>,
    i: usize,
    row_in_out: &mut [f64],
    k: usize,
    rng: &mut Rng,
) {
    ROW_WORK.with(|w| {
        let mut slot = w.borrow_mut();
        let work = RowWork::ensure(&mut slot, k);
        sample_one_row_mvn_with(sweep, i, row_in_out, k, rng, work, None, sweep.tuning, false);
    });
}

/// Tiled Gram+rhs update pinned to one kernel family: the sweep selects
/// SIMD or the scalar seed twin from its [`SweepTuning::backend`]
/// snapshot instead of re-reading the process-global backend per call,
/// so a row never mixes families mid-accumulation.
#[inline]
fn gram_tile_b(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64], simd: bool) {
    if simd {
        crate::linalg::simd::gram_rhs_tile(a, rhs, alpha, xs, vals)
    } else {
        crate::linalg::gram_rhs_tile_scalar(a, rhs, alpha, xs, vals)
    }
}

/// The row conditional over an explicit work area.  Returns the row's
/// fused-SSE partial when `fuse_sse` is set (0.0 otherwise): residuals
/// against the freshly sampled row, summed sequentially in observation
/// order — identical to [`row_sse`] on the same operand.
#[allow(clippy::too_many_arguments)]
fn sample_one_row_mvn_with(
    sweep: &MvnSweep<'_>,
    i: usize,
    row_in_out: &mut [f64],
    k: usize,
    rng: &mut Rng,
    work: &mut RowWork,
    rhs_base: Option<&[f64]>,
    tuning: SweepTuning,
    fuse_sse: bool,
) -> f64 {
    let RowWork { lambda, rhs, tmp, eps, design, xs, vals, stats } = work;
    stats.rows += 1;
    lambda.data_mut().copy_from_slice(sweep.lambda0.data());
    let mean_i = sweep.means.row(i);
    match (rhs_base, &sweep.means) {
        // §Perf PR4 change #3: the shared Λ₀·μ base is hoisted out of
        // the row loop — this copy is bit-identical to the dots below
        (Some(base), MeanSpec::Shared(_)) => rhs.copy_from_slice(base),
        _ => {
            // rhs = Λ₀ μ_i (in place)
            for (r, row0) in rhs.iter_mut().zip(0..k) {
                *r = crate::linalg::dot(sweep.lambda0.row(row0), mean_i);
            }
        }
    }
    // does `xs`/`vals` hold the row's complete gather with raw values
    // when the solve finishes?  (drives the fused-SSE fast path)
    let mut gathered_full = false;
    // Kernel ISA for this row's Gram accumulation and triangular
    // solves: the session's snapshot, strict-masked at call time.
    // Scope note: `dot`/`axpy` calls inside the row (probit preds, rhs
    // dots, fused SSE) keep dispatching on the process global, so the
    // hoist/fused bit-contracts compare like against like; the pinned
    // backend governs the syrk-style kernels and the solves.
    let backend = tuning.backend.effective();
    let use_simd = backend == crate::linalg::Backend::Simd;
    if use_simd {
        stats.rows_simd += 1;
    }
    for view in &sweep.views {
        let alpha = view.alpha;
        match (&view.full_gram, view.probit) {
            (Some(fg), false) => {
                lambda.add_assign(fg);
                view.operand.for_each_design(i, design, |vrow, r| {
                    if r != 0.0 {
                        crate::linalg::axpy(rhs, alpha * r, vrow);
                    }
                });
            }
            _ => {
                // §Perf changes #1+#2: upper-triangle-only accumulation,
                // and (Blocked backend) gather-then-kernel so the inner
                // loops are long enough to vectorize; mirrored once
                // below before the Cholesky.
                if backend != crate::linalg::Backend::Naive {
                    let nnz = view.operand.nnz(i);
                    if tuning.tiled_gram && nnz >= TILE_NNZ_MIN {
                        // §Perf PR4 change #1: high-nnz rows stream
                        // through a bounded B×K tile — gather and syrk
                        // kernel alternate on L1-hot data instead of one
                        // unbounded gather.  Bit-identical to the rank-4
                        // path (GRAM_TILE_ROWS is a multiple of 4, so
                        // the 4-row groups align).
                        stats.gram_tiled += 1;
                        let cap = crate::linalg::GRAM_TILE_ROWS;
                        xs.resize(cap * k, 0.0);
                        vals.resize(cap, 0.0);
                        let mut fill = 0usize;
                        view.operand.for_each_design(i, design, |vrow, r| {
                            let val = if view.probit {
                                let pred = crate::linalg::dot(row_in_out, vrow);
                                NoiseModel::augment_probit(pred, r, rng)
                            } else {
                                r
                            };
                            if fill == cap {
                                gram_tile_b(lambda, rhs, alpha, &xs[..cap * k], &vals[..cap], use_simd);
                                fill = 0;
                            }
                            xs[fill * k..(fill + 1) * k].copy_from_slice(vrow);
                            vals[fill] = val;
                            fill += 1;
                        });
                        if fill > 0 {
                            gram_tile_b(lambda, rhs, alpha, &xs[..fill * k], &vals[..fill], use_simd);
                        }
                    } else {
                        stats.gram_rank4 += 1;
                        xs.clear();
                        vals.clear();
                        view.operand.for_each_design(i, design, |vrow, r| {
                            let val = if view.probit {
                                let pred = crate::linalg::dot(row_in_out, vrow);
                                NoiseModel::augment_probit(pred, r, rng)
                            } else {
                                r
                            };
                            xs.extend_from_slice(vrow);
                            vals.push(val);
                        });
                        if use_simd {
                            crate::linalg::simd::gram_rhs_rank4(lambda, rhs, alpha, xs, vals);
                        } else {
                            crate::linalg::gram_rhs_rank4_scalar(lambda, rhs, alpha, xs, vals);
                        }
                        gathered_full = !view.probit;
                    }
                } else {
                    view.operand.for_each_design(i, design, |vrow, r| {
                        let val = if view.probit {
                            let pred = crate::linalg::dot(row_in_out, vrow);
                            NoiseModel::augment_probit(pred, r, rng)
                        } else {
                            r
                        };
                        crate::linalg::ger_sym_upper_with(lambda, alpha, vrow, backend);
                        crate::linalg::axpy(rhs, alpha * val, vrow);
                    });
                }
            }
        }
    }
    crate::linalg::mirror_upper_to_lower(lambda);
    // in-place Cholesky + three triangular solves (no allocation):
    //   mean = Λ⁻¹ rhs,  u = mean + L⁻ᵀ ε
    if crate::linalg::chol_inplace(lambda).is_err() {
        // numerically degenerate row: fall back to the prior mean
        stats.chol_degenerate += 1;
        row_in_out.copy_from_slice(mean_i);
    } else {
        let l = &*lambda;
        if use_simd {
            crate::linalg::simd::tri_solve_lower_into(l, rhs, tmp);
            crate::linalg::simd::tri_solve_upper_t_into(l, tmp, rhs); // rhs := mean
            rng.fill_normal(eps);
            crate::linalg::simd::tri_solve_upper_t_into(l, eps, tmp); // tmp := L⁻ᵀε
        } else {
            crate::linalg::tri_solve_lower_into_scalar(l, rhs, tmp);
            crate::linalg::tri_solve_upper_t_into_scalar(l, tmp, rhs); // rhs := mean
            rng.fill_normal(eps);
            crate::linalg::tri_solve_upper_t_into_scalar(l, eps, tmp); // tmp := L⁻ᵀε
        }
        for c in 0..k {
            row_in_out[c] = rhs[c] + tmp[c];
        }
    }
    if !fuse_sse {
        return 0.0;
    }
    stats.sse_fused += 1;
    // §Perf PR4 change #2: fused SSE — residuals against the freshly
    // sampled row.  Reuse the in-cache gather when it is complete,
    // otherwise re-walk the fiber; both sum in observation order, so
    // the partial is bit-identical to `row_sse`.
    let view = &sweep.views[0];
    if gathered_full {
        let mut s = 0.0;
        for (t, &v) in vals.iter().enumerate() {
            let e = v - crate::linalg::dot(row_in_out, &xs[t * k..(t + 1) * k]);
            s += e * e;
        }
        s
    } else {
        row_sse(&view.operand, row_in_out, i, design)
    }
}

thread_local! {
    /// per-thread (design rows, values, Hadamard scratch) gather for the
    /// custom-sampler sweep — hoisted out of the hot loop so no `Vec` is
    /// allocated per row (§Perf, same pattern as `RowWork`'s gather)
    static CUSTOM_GATHER: std::cell::RefCell<(Vec<f64>, Vec<f64>, Vec<f64>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Sweep for priors with custom row conditionals (spike-and-slab).
/// These use a single view (GFA loadings each belong to one view).
pub fn sample_side_custom(
    prior: &dyn Prior,
    view: &ViewSlice<'_>,
    latents: &mut Mat,
    pool: &ThreadPool,
    seed: u64,
    iteration: u64,
    side_id: u64,
) {
    let n = latents.rows();
    sample_side_custom_range(prior, view, latents, pool, seed, iteration, side_id, 0..n);
}

/// [`sample_side_custom`] restricted to `rows` — the shard-block variant
/// used by distributed workers.  Values drawn for a row are identical to
/// the full sweep's (per-row RNG streams).  The observations are handed
/// to the prior as gathered design rows, built in per-thread scratch.
#[allow(clippy::too_many_arguments)]
pub fn sample_side_custom_range(
    prior: &dyn Prior,
    view: &ViewSlice<'_>,
    latents: &mut Mat,
    pool: &ThreadPool,
    seed: u64,
    iteration: u64,
    side_id: u64,
    rows: std::ops::Range<usize>,
) {
    sample_side_custom_fused(prior, view, latents, pool, seed, iteration, side_id, rows, false);
}

/// [`sample_side_custom_range`] with the optional fused adaptive-noise
/// SSE pass — the custom-prior twin of
/// [`Engine::sample_mvn_side_fused`].  With `fuse_sse` set, per-row
/// residual partials (against the freshly sampled rows, reusing the
/// already-gathered designs) are written into index-ordered slots during
/// the sweep and folded in row order, bit-identical to a standalone
/// [`view_sse`] over the same operand and latents.
#[allow(clippy::too_many_arguments)]
pub fn sample_side_custom_fused(
    prior: &dyn Prior,
    view: &ViewSlice<'_>,
    latents: &mut Mat,
    pool: &ThreadPool,
    seed: u64,
    iteration: u64,
    side_id: u64,
    rows: std::ops::Range<usize>,
    fuse_sse: bool,
) -> Option<(f64, usize)> {
    let writer = RowWriter::new(latents);
    let start = rows.start;
    let n = rows.len();
    let k = latents.cols();
    let mut sse_rows: Vec<f64> = vec![0.0; if fuse_sse { n } else { 0 }];
    let sse_ptr = SsePtr(sse_rows.as_mut_ptr());
    pool.parallel_for(n, 1, |t| {
        let i = start + t;
        let mut rng = Rng::for_row(seed, iteration, side_id, i as u64);
        CUSTOM_GATHER.with(|g| {
            let (designs, vals, scratch) = &mut *g.borrow_mut();
            designs.clear();
            vals.clear();
            view.operand.for_each_design(i, scratch, |vrow, v| {
                designs.extend_from_slice(vrow);
                vals.push(v);
            });
            // SAFETY: disjoint rows
            let row = unsafe { writer.row_mut(i) };
            prior.sample_row_custom(
                i,
                RowObs { designs, vals, k },
                view.alpha,
                &mut rng,
                row,
            );
            if fuse_sse {
                // residuals against the freshly sampled row over the
                // in-cache gather — same values, same observation order
                // as `row_sse`
                let mut s = 0.0;
                for (o, &v) in vals.iter().enumerate() {
                    let e = v - crate::linalg::dot(row, &designs[o * k..(o + 1) * k]);
                    s += e * e;
                }
                // SAFETY: disjoint slots; the Vec outlives the call
                unsafe { *sse_ptr.0.add(t) = s };
            }
        });
    });
    fuse_sse.then(|| {
        let sse = fold_sse_rows(&sse_rows);
        let cnt: usize = (start..start + n).map(|i| view.operand.nnz(i)).sum();
        (sse, cnt)
    })
}

/// Grain of the SSE reduction — shared by [`view_sse`]'s
/// `parallel_map_reduce` call and [`fold_sse_rows`] so the standalone
/// and fused paths replay the *same* chunk grouping.
const SSE_GRAIN: usize = 8;

/// One target row's residual sum of squares: Σ (r − ⟨target row, design⟩)²
/// over the row's observations, accumulated sequentially in observation
/// order — the shared unit of the standalone [`view_sse`] and the
/// engines' fused pass, which is what makes the two bit-identical.
pub fn row_sse(operand: &Operand<'_>, trow: &[f64], i: usize, scratch: &mut Vec<f64>) -> f64 {
    let mut s = 0.0;
    operand.for_each_design(i, scratch, |vrow, r| {
        let e = r - crate::linalg::dot(trow, vrow);
        s += e * e;
    });
    s
}

/// Fold per-row SSE partials exactly the way
/// `parallel_map_reduce(n, SSE_GRAIN, ..)` folds its chunk partials —
/// row order within chunks of [`threadpool::reduce_chunk_len`], chunks
/// in index order — so the fused-SSE total is bit-identical to
/// [`view_sse`]'s.  (Partials are all ≥ +0.0, so the 0.0 fold seeds
/// cannot flip a sign bit.)
fn fold_sse_rows(slots: &[f64]) -> f64 {
    let n = slots.len();
    if n == 0 {
        return 0.0;
    }
    let chunk = threadpool::reduce_chunk_len(n, SSE_GRAIN);
    slots
        .chunks(chunk)
        .map(|c| {
            let mut s = 0.0;
            for &x in c {
                s += x;
            }
            s
        })
        .fold(0.0, |a, b| a + b)
}

/// Sum of squared residuals over the observed cells of a view — feeds the
/// adaptive-noise Gamma update.  `target` holds the latents of the mode
/// whose fibers `operand` iterates.
///
/// Runs on [`ThreadPool::parallel_map_reduce`], whose chunking depends
/// only on `n` and whose partials fold in chunk order (satellite fix:
/// the old Mutex-push reduction folded in completion order), so the
/// result is bit-identical across runs, thread counts and schedules —
/// and to the engines' fused-SSE pass over the same operand/target,
/// whose per-row slots are folded with the same grouping by
/// [`fold_sse_rows`].
pub fn view_sse(operand: &Operand<'_>, target: &Mat, pool: &ThreadPool) -> (f64, usize) {
    let n = target.rows();
    pool.parallel_map_reduce(
        n,
        SSE_GRAIN,
        |range| {
            let mut s = 0.0;
            let mut c = 0usize;
            let mut scratch = Vec::new();
            for i in range {
                s += row_sse(operand, target.row(i), i, &mut scratch);
                c += operand.nnz(i);
            }
            (s, c)
        },
        (0.0, 0usize),
        |a, b| (a.0 + b.0, a.1 + b.1),
    )
}

/// Build the `DataAccess` for a side of a view.
pub fn access_for<'a>(data: &'a MatrixConfig, target_is_rows: bool) -> DataAccess<'a> {
    match (data, target_is_rows) {
        (MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m), true) => {
            DataAccess::SparseRows(m)
        }
        (MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m), false) => {
            DataAccess::SparseCols(m)
        }
        (MatrixConfig::Dense(m), true) => DataAccess::DenseRows(m),
        (MatrixConfig::Dense(m), false) => DataAccess::DenseCols(m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::priors::{NormalPrior, Prior};

    fn toy_problem() -> (crate::sparse::SparseMatrix, Mat) {
        let mut rng = Rng::new(71);
        let (n, m, k) = (40, 30, 4);
        let mut v = Mat::zeros(m, k);
        rng.fill_normal(v.data_mut());
        let mut trips = Vec::new();
        for i in 0..n {
            for j in 0..m {
                if rng.next_f64() < 0.3 {
                    trips.push((i as u32, j as u32, rng.normal()));
                }
            }
        }
        (crate::sparse::SparseMatrix::from_triplets(n, m, trips), v)
    }

    #[test]
    fn native_sweep_is_thread_count_invariant() {
        let (data, v) = toy_problem();
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(72);
        let mut lat = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat, &mut rng);

        let run = |threads: usize, lat0: &Mat| {
            let pool = ThreadPool::new(threads);
            let mut lat = lat0.clone();
            let spec = prior.mvn_spec().unwrap();
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: spec.means,
                views: vec![ViewSlice::matrix(
                    DataAccess::SparseRows(&data),
                    &v,
                    2.0,
                    false,
                    None,
                )],
                seed: 7,
                iteration: 3,
                side_id: 0,
                tuning: SweepTuning::all_on(),
            };
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(1, &lat);
        let b = run(4, &lat);
        let c = run(7, &lat);
        assert!(a.max_abs_diff(&b) == 0.0, "1 vs 4 threads must be identical");
        assert!(b.max_abs_diff(&c) == 0.0);
        lat = a; // silence unused warning chain
        assert!(lat.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn range_sweep_matches_full_sweep_on_owned_rows() {
        // sampling two disjoint shards must reproduce the full sweep
        // bit-exactly (the determinism invariant distributed training
        // relies on)
        let (data, v) = toy_problem();
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(74);
        let lat0 = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let pool = ThreadPool::new(3);
        let spec = prior.mvn_spec().unwrap();
        let make_sweep = || MvnSweep {
            lambda0: spec.lambda0,
            means: MeanSpec::Shared(match &spec.means {
                MeanSpec::Shared(s) => *s,
                _ => unreachable!(),
            }),
            views: vec![ViewSlice::matrix(
                DataAccess::SparseRows(&data),
                &v,
                2.0,
                false,
                None,
            )],
            seed: 9,
            iteration: 5,
            side_id: 0,
            tuning: SweepTuning::all_on(),
        };
        let mut full = lat0.clone();
        NativeEngine.sample_mvn_side(&make_sweep(), &mut full, &pool);
        let mut sharded = lat0.clone();
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 0..17);
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 17..40);
        assert_eq!(full.max_abs_diff(&sharded), 0.0, "shard sweeps must equal full sweep");
        // empty range is a no-op
        let before = sharded.clone();
        NativeEngine.sample_mvn_side_range(&make_sweep(), &mut sharded, &pool, 7..7);
        assert_eq!(before.max_abs_diff(&sharded), 0.0);
    }

    #[test]
    fn full_gram_path_matches_explicit_dense_iteration() {
        // fully-observed dense data: fast path (full_gram) must equal the
        // naive per-entry accumulation
        let mut rng = Rng::new(73);
        let (n, m, k) = (10, 8, 3);
        let mut dense = Mat::zeros(n, m);
        rng.fill_normal(dense.data_mut());
        let mut v = Mat::zeros(m, k);
        rng.fill_normal(v.data_mut());
        let mut prior = NormalPrior::new(k);
        let mut lat = crate::model::init_latents(n, k, 0.1, &mut rng);
        prior.update_hyper(&lat, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let pool = ThreadPool::new(2);

        let alpha = 1.5;
        let make_sweep = |full: bool| MvnSweep {
            lambda0: spec.lambda0,
            means: MeanSpec::Shared(match &spec.means {
                MeanSpec::Shared(s) => *s,
                _ => unreachable!(),
            }),
            views: vec![ViewSlice::matrix(
                DataAccess::DenseRows(&dense),
                &v,
                alpha,
                false,
                full.then(|| ViewSlice::full_gram_for(&v, alpha)),
            )],
            seed: 11,
            iteration: 0,
            side_id: 0,
            tuning: SweepTuning::all_on(),
        };
        let mut lat_fast = lat.clone();
        NativeEngine.sample_mvn_side(&make_sweep(true), &mut lat_fast, &pool);
        let mut lat_slow = lat.clone();
        NativeEngine.sample_mvn_side(&make_sweep(false), &mut lat_slow, &pool);
        // same RNG streams, same math -> tiny float drift from accumulation order
        assert!(lat_fast.max_abs_diff(&lat_slow) < 1e-6);
        lat = lat_fast;
        assert!(lat.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn view_sse_counts_and_sums() {
        let (data, v) = toy_problem();
        let lat = Mat::zeros(40, 4); // all-zero latents -> residual = r
        let pool = ThreadPool::new(3);
        let op = Operand::Matrix { data: DataAccess::SparseRows(&data), other: &v };
        let (sse, cnt) = view_sse(&op, &lat, &pool);
        let want: f64 = data.triplets().map(|(_, _, r)| r * r).sum();
        assert!((sse - want).abs() < 1e-9);
        assert_eq!(cnt, data.nnz());
    }

    #[test]
    fn two_mode_tensor_operand_is_bit_identical_to_matrix_operand() {
        // the enabling invariant of the N-mode refactor: a 2-mode tensor
        // slice must replay the matrix slice exactly — same design rows
        // in the same order, same RNG streams, zero float drift
        let (data, v) = toy_problem();
        let tensor = crate::sparse::SparseTensor::from_matrix(&data);
        let mut prior = NormalPrior::new(4);
        let mut rng = Rng::new(75);
        let lat0 = crate::model::init_latents(40, 4, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let pool = ThreadPool::new(3);
        let shared = match &spec.means {
            MeanSpec::Shared(s) => *s,
            _ => unreachable!(),
        };
        let run = |slice: ViewSlice<'_>| {
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: MeanSpec::Shared(shared),
                views: vec![slice],
                seed: 13,
                iteration: 2,
                side_id: 0,
                tuning: SweepTuning::all_on(),
            };
            let mut lat = lat0.clone();
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(ViewSlice::matrix(DataAccess::SparseRows(&data), &v, 2.0, false, None));
        let b = run(ViewSlice::tensor_mode(&tensor, 0, vec![(1, &v)], 2.0, false));
        assert_eq!(a.max_abs_diff(&b), 0.0, "2-mode tensor sweep must equal matrix sweep");
        // and the SSE path agrees bit-for-bit too
        let mop = Operand::Matrix { data: DataAccess::SparseRows(&data), other: &v };
        let top = Operand::TensorMode(TensorModeOperand {
            tensor: &tensor,
            mode: 0,
            others: vec![(1, &v)],
        });
        let (s1, c1) = view_sse(&mop, &a, &pool);
        let (s2, c2) = view_sse(&top, &a, &pool);
        assert_eq!(s1, s2);
        assert_eq!(c1, c2);
    }

    #[test]
    fn three_mode_sweep_is_thread_invariant_and_finite() {
        let mut rng = Rng::new(77);
        let (n0, n1, n2, k) = (20, 15, 10, 3);
        let mut f1 = Mat::zeros(n1, k);
        let mut f2 = Mat::zeros(n2, k);
        rng.fill_normal(f1.data_mut());
        rng.fill_normal(f2.data_mut());
        let mut entries = Vec::new();
        for i in 0..n0 {
            for j in 0..n1 {
                for l in 0..n2 {
                    if rng.next_f64() < 0.1 {
                        entries.push((vec![i as u32, j as u32, l as u32], rng.normal()));
                    }
                }
            }
        }
        let tensor = crate::sparse::SparseTensor::from_entries(vec![n0, n1, n2], entries);
        let mut prior = NormalPrior::new(k);
        let lat0 = crate::model::init_latents(n0, k, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let shared = match &spec.means {
            MeanSpec::Shared(s) => *s,
            _ => unreachable!(),
        };
        let run = |threads: usize| {
            let pool = ThreadPool::new(threads);
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: MeanSpec::Shared(shared),
                views: vec![ViewSlice::tensor_mode(
                    &tensor,
                    0,
                    vec![(1, &f1), (2, &f2)],
                    1.5,
                    false,
                )],
                seed: 17,
                iteration: 4,
                side_id: 0,
                tuning: SweepTuning::all_on(),
            };
            let mut lat = lat0.clone();
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let a = run(1);
        let b = run(5);
        assert_eq!(a.max_abs_diff(&b), 0.0, "3-mode sweep must be schedule-invariant");
        assert!(a.data().iter().all(|x| x.is_finite()));
        // design rows really are Hadamard products: check nnz bookkeeping
        let op = Operand::TensorMode(TensorModeOperand {
            tensor: &tensor,
            mode: 0,
            others: vec![(1, &f1), (2, &f2)],
        });
        let mut seen = 0;
        let mut scratch = Vec::new();
        op.for_each_design(0, &mut scratch, |vrow, _| {
            assert_eq!(vrow.len(), k);
            seen += 1;
        });
        assert_eq!(seen, tensor.mode_nnz(0, 0));
        assert_eq!(op.k(), k);
    }

    /// A problem with a heavily skewed row-degree distribution: a few
    /// rows above [`TILE_NNZ_MIN`] (tiled Gram path) and a long sparse
    /// tail (rank-4 path) — exercises the threshold split and the LPT
    /// order at once.
    fn skewed_problem() -> (crate::sparse::SparseMatrix, Mat) {
        let mut rng = Rng::new(91);
        let (n, m, k) = (36, 220, 5);
        let mut v = Mat::zeros(m, k);
        rng.fill_normal(v.data_mut());
        let mut trips = Vec::new();
        for i in 0..n {
            let p = if i % 9 == 0 { 0.8 } else { 0.05 };
            for j in 0..m {
                if rng.next_f64() < p {
                    trips.push((i as u32, j as u32, rng.normal()));
                }
            }
        }
        let data = crate::sparse::SparseMatrix::from_triplets(n, m, trips);
        assert!((0..n).any(|i| data.row_nnz(i) >= TILE_NNZ_MIN), "need tiled rows");
        assert!((0..n).any(|i| data.row_nnz(i) < TILE_NNZ_MIN), "need rank-4 rows");
        (data, v)
    }

    #[test]
    fn sweep_tuning_never_changes_samples() {
        // every §Perf PR4 switch is sample-preserving: baseline vs
        // all-on must produce bit-identical latents, across the tiled /
        // rank-4 threshold split and the LPT reorder
        let (data, v) = skewed_problem();
        let mut prior = NormalPrior::new(5);
        let mut rng = Rng::new(92);
        let lat0 = crate::model::init_latents(36, 5, 0.1, &mut rng);
        prior.update_hyper(&lat0, &mut rng);
        let spec = prior.mvn_spec().unwrap();
        let shared = match &spec.means {
            MeanSpec::Shared(s) => *s,
            _ => unreachable!(),
        };
        // tuning rides on the sweep itself — no process-global involved,
        // so this test cannot race with concurrently-building sessions
        let run = |tuning: SweepTuning, threads: usize| {
            let pool = ThreadPool::new(threads);
            let sweep = MvnSweep {
                lambda0: spec.lambda0,
                means: MeanSpec::Shared(shared),
                views: vec![ViewSlice::matrix(
                    DataAccess::SparseRows(&data),
                    &v,
                    1.7,
                    false,
                    None,
                )],
                seed: 23,
                iteration: 6,
                side_id: 0,
                tuning,
            };
            let mut lat = lat0.clone();
            NativeEngine.sample_mvn_side(&sweep, &mut lat, &pool);
            lat
        };
        let base = run(SweepTuning::baseline(), 3);
        let opt = run(SweepTuning::all_on(), 3);
        let opt1 = run(SweepTuning::all_on(), 1);
        assert_eq!(base.max_abs_diff(&opt), 0.0, "tuning must be sample-preserving");
        assert_eq!(opt.max_abs_diff(&opt1), 0.0, "planned sweep must be thread-invariant");
    }

    #[test]
    fn lpt_order_is_deterministic_and_heaviest_first() {
        let (data, v) = skewed_problem();
        let lam = Mat::eye(5);
        let mu = [0.0; 5];
        let sweep = MvnSweep {
            lambda0: &lam,
            means: MeanSpec::Shared(&mu),
            views: vec![ViewSlice::matrix(DataAccess::SparseRows(&data), &v, 1.0, false, None)],
            seed: 0,
            iteration: 0,
            side_id: 0,
            tuning: SweepTuning::all_on(),
        };
        let order = lpt_order(&sweep, &(0..36)).expect("skewed weights need an order");
        let o2 = lpt_order(&sweep, &(0..36)).unwrap();
        assert_eq!(order, o2, "order must be deterministic");
        // it is a permutation with non-increasing weights
        let mut seen = vec![false; 36];
        let mut prev = usize::MAX;
        for &t in &order {
            assert!(!std::mem::replace(&mut seen[t as usize], true));
            let w = data.row_nnz(t as usize);
            assert!(w <= prev, "weights must be non-increasing");
            prev = w;
        }
        assert!(seen.iter().all(|&s| s));
        // uniform weights: no order
        let dense = Mat::zeros(6, 4);
        let sweep_u = MvnSweep {
            lambda0: &lam,
            means: MeanSpec::Shared(&mu),
            views: vec![ViewSlice::matrix(DataAccess::DenseRows(&dense), &v, 1.0, false, None)],
            seed: 0,
            iteration: 0,
            side_id: 0,
            tuning: SweepTuning::all_on(),
        };
        assert!(lpt_order(&sweep_u, &(0..6)).is_none());
    }

    #[test]
    fn access_for_orientation() {
        let (data, _) = toy_problem();
        let mc = MatrixConfig::SparseUnknown(data.clone());
        assert_eq!(access_for(&mc, true).nnz(0), data.row_nnz(0));
        assert_eq!(access_for(&mc, false).nnz(0), data.col_nnz(0));
        let d = MatrixConfig::Dense(Mat::zeros(3, 5));
        assert_eq!(access_for(&d, true).nnz(2), 5);
        assert_eq!(access_for(&d, false).nnz(4), 3);
    }

    #[test]
    fn dense_cols_access_reads_columns() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let acc = DataAccess::DenseCols(&m);
        let mut got = Vec::new();
        acc.for_each_obs(1, |j, v| got.push((j, v)));
        assert_eq!(got, vec![(0, 2.0), (1, 5.0)]);
    }
}
