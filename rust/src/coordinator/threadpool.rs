//! Fork-join worker pool — the OpenMP substitute (DESIGN.md §4).
//!
//! `parallel_for(n, grain, f)` runs `f(i)` for i in 0..n across the pool
//! with dynamic chunk self-scheduling (an atomic cursor), which is what
//! balances SMURFF's power-law row-degree distribution the way OpenMP's
//! `schedule(dynamic)` + tasks do in the original.  The calling thread
//! participates, so a pool of T threads gives T-way parallelism with
//! T-1 workers.
//!
//! Correctness contract: `f` must be safe to call concurrently for
//! distinct `i` (rows are disjoint in all our uses).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Type-erased job shared with the workers.  The `func` pointer's
/// lifetime is erased; safety is upheld because `parallel_for` does not
/// return until every worker has finished the job (`active == 0`).
struct Job {
    cursor: AtomicUsize,
    n: usize,
    chunk: usize,
    active: AtomicUsize,
    func: *const (dyn Fn(usize) + Sync),
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Shared {
    slot: Mutex<(u64, Option<Arc<Job>>)>, // (generation, job)
    start: Condvar,
    done: Condvar,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    nthreads: usize,
}

impl ThreadPool {
    /// A pool with `nthreads` total lanes (including the caller).
    pub fn new(nthreads: usize) -> ThreadPool {
        let nthreads = nthreads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new((0, None)),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::new();
        for _ in 0..nthreads - 1 {
            let sh = shared.clone();
            handles.push(std::thread::spawn(move || worker_loop(sh)));
        }
        ThreadPool { shared, handles, nthreads }
    }

    /// Pool sized from std::thread::available_parallelism.
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Run `f(i)` for every i in 0..n.  `grain` is the smallest chunk a
    /// worker grabs at once (use ~1 for heavy items, larger for light).
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, grain: usize, f: F) {
        if n == 0 {
            return;
        }
        if self.nthreads == 1 || n <= grain {
            for i in 0..n {
                f(i);
            }
            return;
        }
        // aim for ~8 chunks per lane to absorb imbalance
        let chunk = grain.max(n / (self.nthreads * 8)).max(1);
        let fref: &(dyn Fn(usize) + Sync) = &f;
        let job = Arc::new(Job {
            cursor: AtomicUsize::new(0),
            n,
            chunk,
            active: AtomicUsize::new(self.nthreads - 1),
            // SAFETY: lifetime erased; we block below until active == 0,
            // so no worker touches `f` after this frame ends.
            func: unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(usize) + Sync),
                    *const (dyn Fn(usize) + Sync),
                >(fref as *const _)
            },
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = Some(job.clone());
        }
        self.shared.start.notify_all();
        // caller participates
        run_chunks(&job);
        // wait for all workers to leave the job
        let mut slot = self.shared.slot.lock().unwrap();
        while job.active.load(Ordering::Acquire) != 0 {
            slot = self.shared.done.wait(slot).unwrap();
        }
        slot.1 = None;
    }

    /// Run `f(i)` for every i in 0..n and collect the results into a
    /// `Vec` in index order — parallel execution, deterministic output.
    /// Used by the predict layer (one GEMM per posterior sample, reduced
    /// sequentially so serving results never depend on thread count).
    /// Lock-free: each slot is written exactly once by exactly one lane
    /// (the `parallel_for` contract), the same disjoint-write pattern as
    /// the coordinator's `RowWriter`.
    ///
    /// A panic in `f` aborts the process: letting it unwind would either
    /// hang the fork-join (worker lane never decrements `active`) or
    /// free the output Vec while other lanes still write through the
    /// slot pointer (caller lane).  Abort keeps the unsafe block's
    /// "Vec outlives the call" claim true unconditionally.
    pub fn parallel_collect<T, F>(&self, n: usize, grain: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        struct SlotWriter<T>(*mut Option<T>);
        unsafe impl<T: Send> Send for SlotWriter<T> {}
        unsafe impl<T: Send> Sync for SlotWriter<T> {}

        struct AbortOnUnwind;
        impl Drop for AbortOnUnwind {
            fn drop(&mut self) {
                eprintln!("fatal: panic inside ThreadPool::parallel_collect task");
                std::process::abort();
            }
        }

        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let slots = SlotWriter(out.as_mut_ptr());
        self.parallel_for(n, grain, |i| {
            let guard = AbortOnUnwind;
            let v = f(i);
            std::mem::forget(guard);
            // SAFETY: parallel_for visits each index exactly once, so
            // writes are disjoint; the Vec outlives the (blocking) call,
            // guaranteed even on panic by the abort guard above.
            unsafe { *slots.0.add(i) = Some(v) };
        });
        out.into_iter()
            .map(|t| t.expect("parallel_for visits every index"))
            .collect()
    }

    /// Map chunks of 0..n through `map` and fold the partial results.
    /// `T` must be combinable in any order (sums, maxima, …).
    pub fn parallel_map_reduce<T, M, R>(&self, n: usize, grain: usize, map: M, init: T, reduce: R) -> T
    where
        T: Send,
        M: Fn(std::ops::Range<usize>) -> T + Sync,
        R: Fn(T, T) -> T,
    {
        if n == 0 {
            return init;
        }
        let parts = Mutex::new(Vec::new());
        let chunk = grain.max(n / (self.nthreads * 4)).max(1);
        let nchunks = n.div_ceil(chunk);
        self.parallel_for(nchunks, 1, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            let t = map(lo..hi);
            parts.lock().unwrap().push(t);
        });
        parts.into_inner().unwrap().into_iter().fold(init, |a, b| reduce(a, b))
    }
}

fn run_chunks(job: &Job) {
    let f = unsafe { &*job.func };
    loop {
        let lo = job.cursor.fetch_add(job.chunk, Ordering::Relaxed);
        if lo >= job.n {
            break;
        }
        let hi = (lo + job.chunk).min(job.n);
        for i in lo..hi {
            f(i);
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.0 > seen_gen {
                    seen_gen = slot.0;
                    match &slot.1 {
                        Some(j) => break j.clone(),
                        None => return, // poison: shutdown
                    }
                }
                slot = shared.start.wait(slot).unwrap();
            }
        };
        run_chunks(&job);
        if job.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = shared.slot.lock().unwrap();
            shared.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = None; // poison
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reusable_across_calls() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let acc = AtomicU64::new(0);
            pool.parallel_for(100, 1, |i| {
                acc.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(acc.load(Ordering::Relaxed), 4950, "round {round}");
        }
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let acc = AtomicU64::new(0);
        pool.parallel_for(10, 1, |i| {
            acc.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, 1, |_| panic!("must not run"));
    }

    #[test]
    fn parallel_collect_preserves_index_order() {
        let pool = ThreadPool::new(4);
        let got = pool.parallel_collect(1000, 8, |i| i * 3);
        assert_eq!(got, (0..1000).map(|i| i * 3).collect::<Vec<_>>());
        let empty: Vec<usize> = pool.parallel_collect(0, 1, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn map_reduce_sums() {
        let pool = ThreadPool::new(4);
        let s = pool.parallel_map_reduce(
            1000,
            10,
            |r| r.map(|i| i as u64).sum::<u64>(),
            0u64,
            |a, b| a + b,
        );
        assert_eq!(s, 499_500);
    }

    #[test]
    fn imbalanced_work_completes() {
        // power-law work per item — the SMURFF row-degree situation
        let pool = ThreadPool::new(4);
        let acc = AtomicU64::new(0);
        pool.parallel_for(500, 1, |i| {
            let work = if i == 0 { 200_000 } else { 10 + i % 7 };
            let mut s = 0u64;
            for x in 0..work {
                s = s.wrapping_add(x as u64 ^ (s >> 3));
            }
            acc.fetch_add((s & 1) + 1, Ordering::Relaxed);
        });
        assert!(acc.load(Ordering::Relaxed) >= 500);
    }

    #[test]
    fn borrows_stack_data_safely() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let out: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, 8, |i| {
            out[i].store(data[i] * 2, Ordering::Relaxed);
        });
        for i in 0..1000 {
            assert_eq!(out[i].load(Ordering::Relaxed), 2 * i as u64);
        }
    }
}
