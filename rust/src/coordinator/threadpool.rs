//! Fork-join worker pool — the OpenMP substitute (DESIGN.md §4).
//!
//! `parallel_for(n, grain, f)` runs `f(i)` for i in 0..n across the pool
//! with dynamic chunk self-scheduling (an atomic cursor), which is what
//! balances SMURFF's power-law row-degree distribution the way OpenMP's
//! `schedule(dynamic)` + tasks do in the original.  The calling thread
//! participates, so a pool of T threads gives T-way parallelism with
//! T-1 workers.
//!
//! `parallel_for_lane` additionally hands each invocation its *lane id*
//! (a stable per-thread slot in 0..nthreads) — the hook the Gibbs sweep
//! uses to give every lane a preallocated work area without per-row
//! `thread_local` borrows — and an optional *visit order*, which the
//! sweep planner fills with a descending-nnz (LPT-style) permutation so
//! the heaviest power-law rows are issued first and never strand a lane
//! at the tail of the sweep.
//!
//! Correctness contract: `f` must be safe to call concurrently for
//! distinct `i` (rows are disjoint in all our uses).  Lane ids satisfy:
//! at any instant each lane id is held by at most one OS thread, and a
//! lane's invocations are sequential.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Type-erased job shared with the workers.  The `func` and `order`
/// pointers' lifetimes are erased; safety is upheld because
/// `parallel_for_lane` does not return until every worker has finished
/// the job (`active == 0`).
struct Job {
    cursor: AtomicUsize,
    n: usize,
    chunk: usize,
    active: AtomicUsize,
    /// optional visit order (length n); null = identity order
    order: *const u32,
    func: *const (dyn Fn(usize, usize) + Sync),
}

unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Shared {
    slot: Mutex<(u64, Option<Arc<Job>>)>, // (generation, job)
    start: Condvar,
    done: Condvar,
}

pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    nthreads: usize,
}

impl ThreadPool {
    /// A pool with `nthreads` total lanes (including the caller).
    pub fn new(nthreads: usize) -> ThreadPool {
        let nthreads = nthreads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new((0, None)),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let mut handles = Vec::new();
        for w in 0..nthreads - 1 {
            let sh = shared.clone();
            // worker w owns lane w + 1; the caller is lane 0
            handles.push(std::thread::spawn(move || worker_loop(sh, w + 1)));
        }
        ThreadPool { shared, handles, nthreads }
    }

    /// Pool sized from std::thread::available_parallelism.
    pub fn default_size() -> ThreadPool {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    pub fn nthreads(&self) -> usize {
        self.nthreads
    }

    /// Run `f(i)` for every i in 0..n.  `grain` is the smallest chunk a
    /// worker grabs at once (use ~1 for heavy items, larger for light).
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, grain: usize, f: F) {
        self.parallel_for_lane(n, grain, None, |_, i| f(i));
    }

    /// [`parallel_for`](ThreadPool::parallel_for) with lane ids and an
    /// optional visit order.  `f(lane, i)` runs once for every i in
    /// 0..n; when `order` is given it must be a permutation of 0..n and
    /// items are *issued* in that sequence (an LPT-style schedule when
    /// sorted by descending cost).  `lane` is in 0..nthreads, held by
    /// exactly one OS thread at a time — safe to index per-lane scratch.
    pub fn parallel_for_lane<F: Fn(usize, usize) + Sync>(
        &self,
        n: usize,
        grain: usize,
        order: Option<&[u32]>,
        f: F,
    ) {
        if n == 0 {
            return;
        }
        if let Some(ord) = order {
            assert_eq!(ord.len(), n, "visit order must cover 0..n");
        }
        if self.nthreads == 1 || n <= grain {
            match order {
                Some(ord) => {
                    for &i in ord {
                        f(0, i as usize);
                    }
                }
                None => {
                    for i in 0..n {
                        f(0, i);
                    }
                }
            }
            return;
        }
        // aim for ~8 chunks per lane to absorb imbalance
        let chunk = grain.max(n / (self.nthreads * 8)).max(1);
        let fref: &(dyn Fn(usize, usize) + Sync) = &f;
        let job = Arc::new(Job {
            cursor: AtomicUsize::new(0),
            n,
            chunk,
            active: AtomicUsize::new(self.nthreads - 1),
            order: order.map(|o| o.as_ptr()).unwrap_or(std::ptr::null()),
            // SAFETY: lifetimes erased; we block below until active == 0,
            // so no worker touches `f` or `order` after this frame ends.
            func: unsafe {
                std::mem::transmute::<
                    *const (dyn Fn(usize, usize) + Sync),
                    *const (dyn Fn(usize, usize) + Sync),
                >(fref as *const _)
            },
        });
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = Some(job.clone());
        }
        self.shared.start.notify_all();
        // caller participates as lane 0
        run_chunks(&job, 0);
        // wait for all workers to leave the job
        let mut slot = self.shared.slot.lock().unwrap();
        while job.active.load(Ordering::Acquire) != 0 {
            slot = self.shared.done.wait(slot).unwrap();
        }
        slot.1 = None;
    }

    /// Run `f(i)` for every i in 0..n and collect the results into a
    /// `Vec` in index order — parallel execution, deterministic output.
    /// Used by the predict layer (one GEMM per posterior sample, reduced
    /// sequentially so serving results never depend on thread count) and
    /// by [`view_sse`](crate::coordinator::view_sse)'s per-row partials.
    /// Lock-free: each slot is written exactly once by exactly one lane
    /// (the `parallel_for` contract), the same disjoint-write pattern as
    /// the coordinator's `RowWriter`.
    ///
    /// A panic in `f` aborts the process: letting it unwind would either
    /// hang the fork-join (worker lane never decrements `active`) or
    /// free the output Vec while other lanes still write through the
    /// slot pointer (caller lane).  Abort keeps the unsafe block's
    /// "Vec outlives the call" claim true unconditionally.
    pub fn parallel_collect<T, F>(&self, n: usize, grain: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        struct SlotWriter<T>(*mut Option<T>);
        unsafe impl<T: Send> Send for SlotWriter<T> {}
        unsafe impl<T: Send> Sync for SlotWriter<T> {}

        struct AbortOnUnwind;
        impl Drop for AbortOnUnwind {
            fn drop(&mut self) {
                eprintln!("fatal: panic inside ThreadPool::parallel_collect task");
                std::process::abort();
            }
        }

        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let slots = SlotWriter(out.as_mut_ptr());
        self.parallel_for(n, grain, |i| {
            let guard = AbortOnUnwind;
            let v = f(i);
            std::mem::forget(guard);
            // SAFETY: parallel_for visits each index exactly once, so
            // writes are disjoint; the Vec outlives the (blocking) call,
            // guaranteed even on panic by the abort guard above.
            unsafe { *slots.0.add(i) = Some(v) };
        });
        out.into_iter()
            .map(|t| t.expect("parallel_for visits every index"))
            .collect()
    }

    /// Map chunks of 0..n through `map` and fold the partial results
    /// **in chunk order**.  The chunking depends only on `n` and
    /// `grain` — never on the thread count — and the partials land in
    /// chunk-indexed slots before a sequential fold, so for a
    /// deterministic `map` the result is bit-identical across runs and
    /// across pool sizes (the `view_sse` reproducibility contract).
    pub fn parallel_map_reduce<T, M, R>(&self, n: usize, grain: usize, map: M, init: T, reduce: R) -> T
    where
        T: Send,
        M: Fn(std::ops::Range<usize>) -> T + Sync,
        R: Fn(T, T) -> T,
    {
        if n == 0 {
            return init;
        }
        let chunk = reduce_chunk_len(n, grain);
        let nchunks = n.div_ceil(chunk);
        let parts = self.parallel_collect(nchunks, 1, |c| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(n);
            map(lo..hi)
        });
        parts.into_iter().fold(init, reduce)
    }
}

/// Chunk length of [`ThreadPool::parallel_map_reduce`]'s deterministic
/// reduction: depends only on `n` and `grain` (never the pool size), so
/// the chunk grouping — and therefore any float fold over the chunk
/// partials — is identical across thread counts.  ~256 chunks for large
/// `n` (plenty for any realistic lane count).  The coordinator's
/// fused-SSE fold calls this too, which is what keeps the fused and
/// standalone SSE sums structurally bit-identical.
pub(crate) fn reduce_chunk_len(n: usize, grain: usize) -> usize {
    grain.max(n / 256).max(1)
}

fn run_chunks(job: &Job, lane: usize) {
    let f = unsafe { &*job.func };
    loop {
        let lo = job.cursor.fetch_add(job.chunk, Ordering::Relaxed);
        if lo >= job.n {
            break;
        }
        let hi = (lo + job.chunk).min(job.n);
        if job.order.is_null() {
            for i in lo..hi {
                f(lane, i);
            }
        } else {
            for p in lo..hi {
                // SAFETY: order has length n (checked at submit) and
                // outlives the job (parallel_for_lane blocks until done)
                let i = unsafe { *job.order.add(p) } as usize;
                f(lane, i);
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>, lane: usize) {
    let mut seen_gen = 0u64;
    loop {
        let job = {
            let mut slot = shared.slot.lock().unwrap();
            loop {
                if slot.0 > seen_gen {
                    seen_gen = slot.0;
                    match &slot.1 {
                        Some(j) => break j.clone(),
                        None => return, // poison: shutdown
                    }
                }
                slot = shared.start.wait(slot).unwrap();
            }
        };
        run_chunks(&job, lane);
        if job.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = shared.slot.lock().unwrap();
            shared.done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.0 += 1;
            slot.1 = None; // poison
        }
        self.shared.start.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, 16, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn reusable_across_calls() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let acc = AtomicU64::new(0);
            pool.parallel_for(100, 1, |i| {
                acc.fetch_add(i as u64, Ordering::Relaxed);
            });
            assert_eq!(acc.load(Ordering::Relaxed), 4950, "round {round}");
        }
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let acc = AtomicU64::new(0);
        pool.parallel_for(10, 1, |i| {
            acc.fetch_add(i as u64 + 1, Ordering::Relaxed);
        });
        assert_eq!(acc.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn empty_range_is_noop() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, 1, |_| panic!("must not run"));
    }

    #[test]
    fn ordered_lane_for_covers_exactly_once() {
        for threads in [1, 4] {
            let pool = ThreadPool::new(threads);
            let n = 3000;
            // reversed visit order: every index still hit exactly once
            let order: Vec<u32> = (0..n as u32).rev().collect();
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.parallel_for_lane(n, 4, Some(&order), |lane, i| {
                assert!(lane < pool.nthreads());
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        }
    }

    #[test]
    fn lane_ids_are_exclusive_while_running() {
        // each lane id is held by at most one thread at a time: a flag
        // per lane must never be observed already set on entry
        let pool = ThreadPool::new(4);
        let busy: Vec<AtomicU64> = (0..pool.nthreads()).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for_lane(5000, 1, None, |lane, _i| {
            assert_eq!(busy[lane].swap(1, Ordering::SeqCst), 0, "lane {lane} aliased");
            std::hint::spin_loop();
            busy[lane].store(0, Ordering::SeqCst);
        });
    }

    #[test]
    #[should_panic]
    fn ordered_for_checks_length() {
        let pool = ThreadPool::new(2);
        let order = vec![0u32, 1];
        pool.parallel_for_lane(3, 1, Some(&order), |_, _| {});
    }

    #[test]
    fn parallel_collect_preserves_index_order() {
        let pool = ThreadPool::new(4);
        let got = pool.parallel_collect(1000, 8, |i| i * 3);
        assert_eq!(got, (0..1000).map(|i| i * 3).collect::<Vec<_>>());
        let empty: Vec<usize> = pool.parallel_collect(0, 1, |i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn map_reduce_sums() {
        let pool = ThreadPool::new(4);
        let s = pool.parallel_map_reduce(
            1000,
            10,
            |r| r.map(|i| i as u64).sum::<u64>(),
            0u64,
            |a, b| a + b,
        );
        assert_eq!(s, 499_500);
    }

    #[test]
    fn map_reduce_is_bit_identical_across_thread_counts() {
        // float partial sums: chunking and fold order must not depend on
        // the pool size (satellite fix: chunk-indexed slots, ordered fold)
        let xs: Vec<f64> = (0..10_007).map(|i| ((i * 37 + 11) % 101) as f64 * 0.001 + 1e-9).collect();
        let run = |threads: usize| {
            let pool = ThreadPool::new(threads);
            pool.parallel_map_reduce(
                xs.len(),
                8,
                |r| r.map(|i| xs[i] * xs[i]).sum::<f64>(),
                0.0f64,
                |a, b| a + b,
            )
        };
        let a = run(1);
        let b = run(4);
        let c = run(7);
        assert_eq!(a.to_bits(), b.to_bits(), "1 vs 4 threads");
        assert_eq!(b.to_bits(), c.to_bits(), "4 vs 7 threads");
    }

    #[test]
    fn imbalanced_work_completes() {
        // power-law work per item — the SMURFF row-degree situation
        let pool = ThreadPool::new(4);
        let acc = AtomicU64::new(0);
        pool.parallel_for(500, 1, |i| {
            let work = if i == 0 { 200_000 } else { 10 + i % 7 };
            let mut s = 0u64;
            for x in 0..work {
                s = s.wrapping_add(x as u64 ^ (s >> 3));
            }
            acc.fetch_add((s & 1) + 1, Ordering::Relaxed);
        });
        assert!(acc.load(Ordering::Relaxed) >= 500);
    }

    #[test]
    fn borrows_stack_data_safely() {
        let pool = ThreadPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let out: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(1000, 8, |i| {
            out[i].store(data[i] * 2, Ordering::Relaxed);
        });
        for i in 0..1000 {
            assert_eq!(out[i].load(Ordering::Relaxed), 2 * i as u64);
        }
    }
}
