//! Synthetic workload generators — the substitutions for the paper's
//! datasets (DESIGN.md §4):
//!
//! * [`chembl_synth`] — ChEMBL-like compound×protein IC50 matrix with
//!   ECFP-like sparse binary fingerprints as side information.  Power-law
//!   row degrees reproduce the load imbalance the paper's OpenMP-task
//!   parallelism targets; the fingerprints are *correlated with the
//!   latent structure* so Macau's link matrix genuinely helps, as in the
//!   paper's compound-activity use case.
//! * [`movielens_like`] — small ratings matrix for quickstarts/tests.
//! * [`power_law_matrix`] — Zipf row-degree sparse matrix, the workload
//!   shape behind the nnz-weighted sweep schedule (`bench sweep`).
//! * [`gfa_study_data`] — the Bunte et al. (2015) *simulated study*:
//!   multiple views sharing row factors, with group-sparse structure
//!   (each factor active in a known subset of views).

use crate::linalg::Mat;
use crate::rng::Rng;
use crate::sparse::SparseMatrix;

use super::SideInfo;

/// Spec for the ChEMBL-like generator.
#[derive(Debug, Clone)]
pub struct ChemblSpec {
    pub compounds: usize,
    pub proteins: usize,
    /// target number of observed IC50 cells
    pub nnz: usize,
    /// ground-truth latent dimension
    pub rank: usize,
    /// observation noise stddev
    pub noise: f64,
    /// number of fingerprint bits (ECFP-like)
    pub fp_bits: usize,
    /// expected on-bits per compound
    pub fp_density: usize,
    /// Zipf exponent for per-compound activity counts (load imbalance)
    pub degree_exponent: f64,
    pub seed: u64,
}

impl Default for ChemblSpec {
    fn default() -> Self {
        ChemblSpec {
            compounds: 2000,
            proteins: 200,
            nnz: 40_000,
            rank: 8,
            noise: 0.4,
            fp_bits: 1024,
            fp_density: 40,
            degree_exponent: 1.1,
            seed: 42,
        }
    }
}

/// Output of [`chembl_synth`].
pub struct ChemblData {
    /// observed IC50-like activities (train + test together)
    pub activity: SparseMatrix,
    /// sparse binary fingerprints, compounds × fp_bits
    pub fingerprints_sparse: SideInfo,
    /// the same fingerprints densified (the paper uses both formats)
    pub fingerprints_dense: SideInfo,
    /// ground-truth factors (for recovery tests)
    pub u_true: Mat,
    pub v_true: Mat,
}

/// Generate a ChEMBL-like compound-activity dataset.
///
/// Latent structure: `U = F_real · W + noise` so the fingerprints carry
/// real information about the compound factors (this is the property
/// Macau exploits); `activity = U Vᵀ + ε`, sampled at power-law-degree
/// cells, values shifted to an IC50-like scale (pIC50 ≈ 6 ± 1.5).
pub fn chembl_synth(spec: &ChemblSpec) -> ChemblData {
    let mut rng = Rng::from_parts(spec.seed, 0xC4E3);
    let k = spec.rank;

    // ECFP-like fingerprints: random sparse binary rows
    let mut fp_trips = Vec::new();
    for i in 0..spec.compounds {
        // per-compound bit count varies a bit
        let bits = (spec.fp_density as f64 * (0.5 + rng.next_f64())) as usize;
        for _ in 0..bits.max(1) {
            fp_trips.push((i as u32, rng.next_below(spec.fp_bits) as u32, 1.0));
        }
    }
    let fp = SparseMatrix::from_triplets(spec.compounds, spec.fp_bits, fp_trips);

    // link weights W: fp_bits × k, sparse-ish but strong — the
    // fingerprints must genuinely predict the compound factors for the
    // Macau use case to be reproducible (paper §4)
    let mut w = Mat::zeros(spec.fp_bits, k);
    for i in 0..spec.fp_bits {
        for j in 0..k {
            if rng.next_f64() < 0.3 {
                w[(i, j)] = rng.normal();
            }
        }
    }

    // U = normalize(F W) + small idiosyncratic noise (SNR >> 1)
    let mut u = Mat::zeros(spec.compounds, k);
    for i in 0..spec.compounds {
        let (cols, _) = fp.row(i);
        let urow = u.row_mut(i);
        for &c in cols {
            for j in 0..k {
                urow[j] += w[(c as usize, j)];
            }
        }
        let scale = 1.0 / (cols.len().max(1) as f64).sqrt();
        for j in 0..k {
            urow[j] = urow[j] * scale + 0.15 * rng.normal();
        }
    }

    let mut v = Mat::zeros(spec.proteins, k);
    rng.fill_normal(v.data_mut());

    // power-law compound degrees (Zipf over rank order)
    let mut weights: Vec<f64> = (0..spec.compounds)
        .map(|i| 1.0 / ((i + 1) as f64).powf(spec.degree_exponent))
        .collect();
    // shuffle so heavy compounds are spread across row indices
    rng.shuffle(&mut weights);
    let wsum: f64 = weights.iter().sum();

    let mut trips = Vec::with_capacity(spec.nnz);
    let mut seen = std::collections::HashSet::with_capacity(spec.nnz * 2);
    for (i, wi) in weights.iter().enumerate() {
        let cnt = ((wi / wsum) * spec.nnz as f64).round() as usize;
        for _ in 0..cnt.max(1).min(spec.proteins) {
            let j = rng.next_below(spec.proteins);
            if !seen.insert((i as u32, j as u32)) {
                continue;
            }
            let mean = crate::linalg::dot(u.row(i), v.row(j));
            // pIC50-like scale
            let val = 6.0 + mean + spec.noise * rng.normal();
            trips.push((i as u32, j as u32, val));
        }
    }

    let activity = SparseMatrix::from_triplets(spec.compounds, spec.proteins, trips);
    let fp_dense = fp.to_dense();
    ChemblData {
        activity,
        fingerprints_sparse: SideInfo::Sparse(fp),
        fingerprints_dense: SideInfo::Dense(fp_dense),
        u_true: u,
        v_true: v,
    }
}

/// Small MovieLens-like ratings matrix from a rank-`8` ground truth,
/// ratings clipped to [1, 5].  Returns (train, test) split by `test_frac`.
pub fn movielens_like(
    users: usize,
    movies: usize,
    nnz: usize,
    test_frac: f64,
    seed: u64,
) -> (SparseMatrix, SparseMatrix) {
    let mut rng = Rng::from_parts(seed, 0x30DA);
    let k = 8;
    let mut u = Mat::zeros(users, k);
    let mut v = Mat::zeros(movies, k);
    rng.fill_normal(u.data_mut());
    rng.fill_normal(v.data_mut());
    let scale = 1.0 / (k as f64).sqrt();

    let mut seen = std::collections::HashSet::with_capacity(nnz * 2);
    let mut trips = Vec::with_capacity(nnz);
    while trips.len() < nnz.min(users * movies * 9 / 10) {
        let i = rng.next_below(users);
        let j = rng.next_below(movies);
        if !seen.insert((i as u32, j as u32)) {
            continue;
        }
        let raw = 3.0 + 1.2 * scale * crate::linalg::dot(u.row(i), v.row(j)) + 0.3 * rng.normal();
        trips.push((i as u32, j as u32, raw.clamp(1.0, 5.0)));
    }
    let all = SparseMatrix::from_triplets(users, movies, trips);
    if test_frac > 0.0 {
        super::split_train_test(&all, test_frac, seed ^ 0x7E57)
    } else {
        (all, SparseMatrix::from_triplets(users, movies, Vec::<(u32, u32, f64)>::new()))
    }
}

/// Power-law row-popularity distribution: Zipf weights
/// ∝ (rank+1)^-exponent over degree ranks, with the rank→row map
/// shuffled so heavy rows are spread over the index space.  This is the
/// degree machinery of [`power_law_matrix`], factored out (ISSUE 10) so
/// the serving load generator can replay the *same* skew as the data
/// the paper's workloads are shaped like: a few promiscuous
/// compounds/users drawing most of the traffic, a long cold tail.
pub struct PowerLawRows {
    /// rank → row index (rank 0 = heaviest)
    row_of_rank: Vec<usize>,
    /// Zipf weight per rank, 1/(rank+1)^exponent
    weights: Vec<f64>,
    /// Σ weights
    total: f64,
    /// cumulative weights (inclusive), for inverse-CDF sampling
    cum: Vec<f64>,
}

impl PowerLawRows {
    /// Build over `rows` rows, consuming exactly one `shuffle` from the
    /// caller's generator — the same draw order [`power_law_matrix`]
    /// has always used, so matrices built through this stay
    /// bit-identical to the pre-refactor generator.
    pub fn with_rng(rows: usize, exponent: f64, rng: &mut Rng) -> PowerLawRows {
        assert!(rows > 0);
        let weights: Vec<f64> =
            (0..rows).map(|r| 1.0 / ((r + 1) as f64).powf(exponent)).collect();
        let total: f64 = weights.iter().sum();
        let mut row_of_rank: Vec<usize> = (0..rows).collect();
        rng.shuffle(&mut row_of_rank);
        let mut cum = Vec::with_capacity(rows);
        let mut acc = 0.0;
        for w in &weights {
            acc += w;
            cum.push(acc);
        }
        PowerLawRows { row_of_rank, weights, total, cum }
    }

    /// Standalone constructor with its own deterministic stream.
    pub fn new(rows: usize, exponent: f64, seed: u64) -> PowerLawRows {
        let mut rng = Rng::from_parts(seed, 0x90_17);
        PowerLawRows::with_rng(rows, exponent, &mut rng)
    }

    /// Number of rows in the universe.
    pub fn len(&self) -> usize {
        self.row_of_rank.len()
    }

    pub fn is_empty(&self) -> bool {
        self.row_of_rank.is_empty()
    }

    /// The row holding degree rank `rank` (0 = heaviest).
    pub fn row_of_rank(&self, rank: usize) -> usize {
        self.row_of_rank[rank]
    }

    /// Expected degree of the rank-th heaviest row when `nnz` draws are
    /// spread over the distribution, clamped to [1, max_degree] — the
    /// exact rounding [`power_law_matrix`] sizes its rows with.
    pub fn expected_degree(&self, rank: usize, nnz: usize, max_degree: usize) -> usize {
        ((nnz as f64 * self.weights[rank] / self.total).round() as usize).clamp(1, max_degree)
    }

    /// Draw one row with probability ∝ its Zipf weight (inverse-CDF on
    /// the cumulative weights) — the loadgen request stream.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64() * self.total;
        // first rank whose cumulative weight reaches u
        let rank = self.cum.partition_point(|&c| c < u).min(self.cum.len() - 1);
        self.row_of_rank[rank]
    }
}

/// Sparse matrix whose **row degrees follow a power law** — the
/// compound-activity shape (a few promiscuous compounds with thousands
/// of measurements, a long tail with a handful) that the nnz-weighted
/// sweep schedule exists for.  Row i (after a deterministic shuffle so
/// heavy rows are spread over the index space) gets an expected degree
/// ∝ (rank+1)^-exponent; values come from a rank-8 ground truth plus
/// noise.  Duplicate (i, j) draws merge in `from_triplets`, so the
/// realised nnz can land slightly under `nnz`.
pub fn power_law_matrix(
    rows: usize,
    cols: usize,
    nnz: usize,
    exponent: f64,
    seed: u64,
) -> SparseMatrix {
    assert!(rows > 0 && cols > 0);
    let mut rng = Rng::from_parts(seed, 0x90_17);
    let k = 8;
    let mut u = Mat::zeros(rows, k);
    let mut v = Mat::zeros(cols, k);
    rng.fill_normal(u.data_mut());
    rng.fill_normal(v.data_mut());
    let scale = 1.0 / (k as f64).sqrt();

    // the shared degree machinery (consumes the shuffle draw exactly
    // where the weights+shuffle block used to sit)
    let dist = PowerLawRows::with_rng(rows, exponent, &mut rng);

    let mut trips = Vec::with_capacity(nnz);
    for rank in 0..dist.len() {
        let i = dist.row_of_rank(rank);
        let want = dist.expected_degree(rank, nnz, cols);
        for _ in 0..want {
            let j = rng.next_below(cols);
            let val = scale * crate::linalg::dot(u.row(i), v.row(j)) + 0.3 * rng.normal();
            trips.push((i as u32, j as u32, val));
        }
    }
    SparseMatrix::from_triplets(rows, cols, trips)
}

/// Spec for the GFA simulated study (Bunte et al. 2015, §"Simulated study").
#[derive(Debug, Clone)]
pub struct GfaSpec {
    /// shared sample count (rows of every view)
    pub n: usize,
    /// columns per view
    pub view_cols: Vec<usize>,
    /// total latent factors
    pub k: usize,
    /// for each factor, which views it is active in (group-sparsity
    /// ground truth); length k, each a bitmask over views
    pub activity: Vec<Vec<bool>>,
    pub noise: f64,
    pub seed: u64,
}

impl Default for GfaSpec {
    fn default() -> Self {
        // 3 views, 6 factors: 2 shared by all, 1 per pair, 1 private —
        // the canonical group-factor pattern of the simulated study.
        GfaSpec {
            n: 100,
            view_cols: vec![60, 40, 30],
            k: 6,
            activity: vec![
                vec![true, true, true],
                vec![true, true, true],
                vec![true, true, false],
                vec![true, false, true],
                vec![false, true, true],
                vec![true, false, false],
            ],
            noise: 0.3,
            seed: 7,
        }
    }
}

/// Output of [`gfa_study_data`].
pub struct GfaData {
    /// one dense view per entry of `view_cols`, all sharing row factors
    pub views: Vec<Mat>,
    pub z_true: Mat,
    /// per-view loadings with the group-sparse zero pattern applied
    pub w_true: Vec<Mat>,
}

/// Generate the GFA simulated study: X_v = Z W_vᵀ + noise, with factor f
/// active in view v only where `activity[f][v]`.
pub fn gfa_study_data(spec: &GfaSpec) -> GfaData {
    assert!(spec.activity.len() == spec.k, "activity must list every factor");
    let nviews = spec.view_cols.len();
    for a in &spec.activity {
        assert_eq!(a.len(), nviews);
    }
    let mut rng = Rng::from_parts(spec.seed, 0x6FA);
    let mut z = Mat::zeros(spec.n, spec.k);
    rng.fill_normal(z.data_mut());

    let mut views = Vec::new();
    let mut w_true = Vec::new();
    for (v, &cols) in spec.view_cols.iter().enumerate() {
        let mut w = Mat::zeros(cols, spec.k);
        for f in 0..spec.k {
            if spec.activity[f][v] {
                for j in 0..cols {
                    w[(j, f)] = rng.normal();
                }
            }
        }
        let mut x = crate::linalg::gemm(&z, &w.transpose());
        for val in x.data_mut().iter_mut() {
            *val += spec.noise * rng.normal();
        }
        views.push(x);
        w_true.push(w);
    }
    GfaData { views, z_true: z, w_true }
}

/// Spec for the synthetic CP/PARAFAC tensor generator.
#[derive(Debug, Clone)]
pub struct CpSpec {
    /// mode sizes (N ≥ 2)
    pub dims: Vec<usize>,
    /// ground-truth CP rank
    pub rank: usize,
    /// target number of observed cells
    pub nnz: usize,
    /// observation noise stddev
    pub noise: f64,
    pub seed: u64,
}

impl Default for CpSpec {
    fn default() -> Self {
        CpSpec { dims: vec![40, 30, 20], rank: 4, nnz: 6_000, noise: 0.1, seed: 42 }
    }
}

/// Output of [`cp_tensor_synth`].
pub struct CpData {
    /// observed cells (train + test together)
    pub tensor: crate::sparse::SparseTensor,
    /// ground-truth factor matrices, one per mode
    pub factors_true: Vec<Mat>,
    pub noise: f64,
}

/// Generate a synthetic N-mode CP tensor — the stand-in for the
/// compound × target × assay-condition workload of the upstream system:
/// per mode a `dim × rank` factor with N(0, 1/⁴√(rank·N)) entries so the
/// reconstructed signal has roughly unit variance, observed at `nnz`
/// uniformly random cells with N(0, noise²) measurement error.
pub fn cp_tensor_synth(spec: &CpSpec) -> CpData {
    assert!(spec.dims.len() >= 2, "CP tensor needs at least 2 modes");
    let mut rng = Rng::from_parts(spec.seed, 0xCB7E);
    let nmodes = spec.dims.len();
    // scale so Var[Π_m f_m] = (scale²)^N · rank ≈ 1
    let scale = (1.0 / spec.rank as f64).powf(0.5 / nmodes as f64);
    let factors: Vec<Mat> = spec
        .dims
        .iter()
        .map(|&d| {
            let mut f = Mat::zeros(d, spec.rank);
            rng.fill_normal(f.data_mut());
            f.scale(scale);
            f
        })
        .collect();
    let mut flat = Vec::with_capacity(spec.nnz * nmodes);
    let mut vals = Vec::with_capacity(spec.nnz);
    let mut coord = vec![0u32; nmodes];
    for _ in 0..spec.nnz {
        for (m, c) in coord.iter_mut().enumerate() {
            *c = rng.next_below(spec.dims[m]) as u32;
        }
        let mut v = 0.0;
        for r in 0..spec.rank {
            let mut p = 1.0;
            for (m, f) in factors.iter().enumerate() {
                p *= f[(coord[m] as usize, r)];
            }
            v += p;
        }
        flat.extend_from_slice(&coord);
        vals.push(v + spec.noise * rng.normal());
    }
    CpData {
        tensor: crate::sparse::SparseTensor::from_flat(spec.dims.clone(), &flat, &vals),
        factors_true: factors,
        noise: spec.noise,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chembl_shapes_and_scale() {
        let spec = ChemblSpec { compounds: 300, proteins: 50, nnz: 5000, ..Default::default() };
        let d = chembl_synth(&spec);
        assert_eq!(d.activity.nrows(), 300);
        assert_eq!(d.activity.ncols(), 50);
        assert!(d.activity.nnz() > 1000, "nnz {}", d.activity.nnz());
        // IC50-like scale
        let m = d.activity.mean_value();
        assert!((4.0..8.0).contains(&m), "mean {m}");
        assert_eq!(d.fingerprints_sparse.nrows(), 300);
        assert_eq!(d.fingerprints_sparse.nfeatures(), 1024);
    }

    #[test]
    fn cp_tensor_has_unit_scale_signal_and_reproducible() {
        let spec = CpSpec { dims: vec![25, 20, 15], rank: 3, nnz: 3_000, noise: 0.1, seed: 7 };
        let d = cp_tensor_synth(&spec);
        assert_eq!(d.tensor.nmodes(), 3);
        assert_eq!(d.tensor.dims(), &[25, 20, 15]);
        // duplicates merge, so nnz can shrink a little but not much
        assert!(d.tensor.nnz() > 2_800, "nnz {}", d.tensor.nnz());
        let var = crate::util::variance(d.tensor.vals());
        assert!((0.2..5.0).contains(&var), "signal variance {var}");
        // deterministic in the seed
        let d2 = cp_tensor_synth(&spec);
        assert_eq!(d.tensor.vals(), d2.tensor.vals());
        assert_eq!(d.factors_true.len(), 3);
    }

    #[test]
    fn chembl_degrees_are_power_law_ish() {
        let spec = ChemblSpec { compounds: 500, proteins: 100, nnz: 10_000, ..Default::default() };
        let d = chembl_synth(&spec);
        let mut hist = d.activity.row_nnz_histogram();
        hist.sort_unstable_by(|a, b| b.cmp(a));
        // heavy head: top 10% of compounds own > 25% of observations
        let top: usize = hist[..50].iter().sum();
        assert!(top * 4 > d.activity.nnz(), "top {top} of {}", d.activity.nnz());
        // tail exists
        assert!(*hist.last().unwrap() <= 2);
    }

    #[test]
    fn chembl_fingerprints_predict_factors() {
        // sanity: same fingerprints (dense vs sparse) and correlated latents
        let spec = ChemblSpec { compounds: 100, proteins: 30, nnz: 2000, ..Default::default() };
        let d = chembl_synth(&spec);
        if let (SideInfo::Sparse(s), SideInfo::Dense(dn)) =
            (&d.fingerprints_sparse, &d.fingerprints_dense)
        {
            assert_eq!(&s.to_dense(), dn);
        } else {
            panic!("wrong side-info kinds");
        }
        // u_true should have signal: nonzero variance across compounds
        let var = crate::util::variance(d.u_true.data());
        assert!(var > 0.01);
    }

    #[test]
    fn chembl_deterministic() {
        let spec = ChemblSpec { compounds: 100, proteins: 20, nnz: 1000, ..Default::default() };
        let a = chembl_synth(&spec);
        let b = chembl_synth(&spec);
        assert_eq!(
            a.activity.triplets().collect::<Vec<_>>(),
            b.activity.triplets().collect::<Vec<_>>()
        );
    }

    #[test]
    fn power_law_rows_sample_is_deterministic_and_head_heavy() {
        let dist = PowerLawRows::new(200, 1.0, 9);
        // deterministic: same seed, same stream of draws
        let draws = |rng: &mut Rng| (0..5_000).map(|_| dist.sample(rng)).collect::<Vec<usize>>();
        let a = draws(&mut Rng::from_parts(42, 1));
        let b = draws(&mut Rng::from_parts(42, 1));
        assert_eq!(a, b);
        // every draw is a valid row
        assert!(a.iter().all(|&r| r < 200));
        // head-heavy: the 20 heaviest ranks own well over uniform share
        let head: std::collections::HashSet<usize> =
            (0..20).map(|rank| dist.row_of_rank(rank)).collect();
        let head_hits = a.iter().filter(|r| head.contains(r)).count();
        assert!(
            head_hits * 2 > a.len(),
            "top-10% rows drew {head_hits}/{} — not power-law shaped",
            a.len()
        );
        // expected_degree reproduces the generator's rounding exactly
        let nnz = 10_000;
        assert!(dist.expected_degree(0, nnz, usize::MAX) > dist.expected_degree(199, nnz, usize::MAX));
        assert_eq!(dist.expected_degree(199, 10, 50), 1, "tail rows are clamped up to 1");
    }

    #[test]
    fn movielens_values_in_range() {
        let (train, test) = movielens_like(100, 80, 2000, 0.2, 3);
        assert_eq!(train.nrows(), 100);
        for (_, _, v) in train.triplets().chain(test.triplets()) {
            assert!((1.0..=5.0).contains(&v));
        }
        let total = train.nnz() + test.nnz();
        assert!(total >= 1900, "requested 2000 cells, got {total}");
    }

    #[test]
    fn gfa_respects_activity_pattern() {
        let spec = GfaSpec::default();
        let d = gfa_study_data(&spec);
        assert_eq!(d.views.len(), 3);
        assert_eq!(d.views[0].rows(), spec.n);
        assert_eq!(d.views[1].cols(), 40);
        // factor 5 is private to view 0: W for views 1,2 must be zero there
        for v in [1, 2] {
            let w = &d.w_true[v];
            for j in 0..w.rows() {
                assert_eq!(w[(j, 5)], 0.0);
            }
        }
        // and nonzero (generically) in view 0
        let w0 = &d.w_true[0];
        assert!((0..w0.rows()).any(|j| w0[(j, 5)] != 0.0));
    }

    #[test]
    fn gfa_views_carry_shared_signal() {
        let d = gfa_study_data(&GfaSpec::default());
        // X_v should be far from pure noise: ‖X‖ >> noise * sqrt(cells)
        for x in &d.views {
            let cells = (x.rows() * x.cols()) as f64;
            assert!(x.norm() > 2.0 * 0.3 * cells.sqrt());
        }
    }
}
