//! Data layer: the three input-matrix kinds of Table 1, side information,
//! train/test splitting and the synthetic workload generators that stand
//! in for the paper's datasets (DESIGN.md §4).

pub mod generators;

pub use generators::{
    chembl_synth, cp_tensor_synth, gfa_study_data, movielens_like, power_law_matrix, ChemblSpec,
    CpData, CpSpec, GfaSpec, PowerLawRows,
};

use crate::linalg::Mat;
use crate::sparse::SparseMatrix;

/// The matrix-to-factor, in the three flavours SMURFF supports
/// (Table 1, "Input Matrices").
#[derive(Debug, Clone)]
pub enum MatrixConfig {
    /// Sparse, unobserved cells are *unknown* (classic recommender data).
    SparseUnknown(SparseMatrix),
    /// Sparse, unobserved cells are *known zeros* (fully-known data in
    /// sparse storage) — the precision term uses the full VᵀV.
    SparseFull(SparseMatrix),
    /// Dense, every cell observed.
    Dense(Mat),
}

impl MatrixConfig {
    pub fn nrows(&self) -> usize {
        match self {
            MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m) => m.nrows(),
            MatrixConfig::Dense(m) => m.rows(),
        }
    }

    pub fn ncols(&self) -> usize {
        match self {
            MatrixConfig::SparseUnknown(m) | MatrixConfig::SparseFull(m) => m.ncols(),
            MatrixConfig::Dense(m) => m.cols(),
        }
    }

    /// Number of *observed* cells (training likelihood terms).
    pub fn nobs(&self) -> usize {
        match self {
            MatrixConfig::SparseUnknown(m) => m.nnz(),
            MatrixConfig::SparseFull(m) => m.nrows() * m.ncols(),
            MatrixConfig::Dense(m) => m.rows() * m.cols(),
        }
    }

    /// Whether every cell is observed (fully-known data: the per-row
    /// precision term is the same full Gram VᵀV for all rows).
    pub fn fully_observed(&self) -> bool {
        !matches!(self, MatrixConfig::SparseUnknown(_))
    }

    /// Mean of the observed values.
    pub fn mean(&self) -> f64 {
        match self {
            MatrixConfig::SparseUnknown(m) => m.mean_value(),
            MatrixConfig::SparseFull(m) => {
                // zeros count as observations
                m.mean_value() * m.nnz() as f64 / (m.nrows() * m.ncols()) as f64
            }
            MatrixConfig::Dense(m) => crate::util::mean(m.data()),
        }
    }
}

/// Side information for the rows or columns of R (the Macau `F` matrix).
#[derive(Debug, Clone)]
pub enum SideInfo {
    Dense(Mat),
    Sparse(SparseMatrix),
}

impl SideInfo {
    pub fn nrows(&self) -> usize {
        match self {
            SideInfo::Dense(m) => m.rows(),
            SideInfo::Sparse(m) => m.nrows(),
        }
    }

    pub fn nfeatures(&self) -> usize {
        match self {
            SideInfo::Dense(m) => m.cols(),
            SideInfo::Sparse(m) => m.ncols(),
        }
    }

    /// y = F · x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SideInfo::Dense(m) => crate::linalg::matvec(m, x),
            SideInfo::Sparse(m) => m.spmv(x),
        }
    }

    /// y = Fᵀ · x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        match self {
            SideInfo::Dense(m) => crate::linalg::matvec_t(m, x),
            SideInfo::Sparse(m) => m.spmv_t(x),
        }
    }

    /// Row i of F written into a dense scratch buffer.
    pub fn row_dense(&self, i: usize, out: &mut [f64]) {
        out.fill(0.0);
        match self {
            SideInfo::Dense(m) => out.copy_from_slice(m.row(i)),
            SideInfo::Sparse(m) => {
                let (cols, vals) = m.row(i);
                for (&c, &v) in cols.iter().zip(vals) {
                    out[c as usize] = v;
                }
            }
        }
    }
}

/// Held-out test set: explicit (row, col, value) cells.
#[derive(Debug, Clone, Default)]
pub struct TestSet {
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl TestSet {
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn from_sparse(m: &SparseMatrix) -> TestSet {
        let mut t = TestSet::default();
        for (r, c, v) in m.triplets() {
            t.rows.push(r);
            t.cols.push(c);
            t.vals.push(v);
        }
        t
    }
}

/// Held-out test cells of an N-mode tensor view: explicit coordinate
/// tuples (one vector per mode) plus values — the tensor analogue of
/// [`TestSet`].
#[derive(Debug, Clone, Default)]
pub struct TensorTestSet {
    /// `coords[m][cell]` — the cell's coordinate along mode m
    pub coords: Vec<Vec<u32>>,
    pub vals: Vec<f64>,
}

impl TensorTestSet {
    pub fn len(&self) -> usize {
        self.vals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vals.is_empty()
    }

    pub fn nmodes(&self) -> usize {
        self.coords.len()
    }

    /// Every entry of `t` as a test set, in canonical order (for a
    /// 2-mode tensor this is exactly [`TestSet::from_sparse`]'s order).
    pub fn from_tensor(t: &crate::sparse::SparseTensor) -> TensorTestSet {
        let nmodes = t.nmodes();
        let mut s = TensorTestSet { coords: vec![Vec::with_capacity(t.nnz()); nmodes], vals: Vec::with_capacity(t.nnz()) };
        for (e, v) in t.entry_ids() {
            for (m, c) in s.coords.iter_mut().enumerate() {
                c.push(t.coord(m, e));
            }
            s.vals.push(v);
        }
        s
    }
}

/// Split a sparse tensor's entries into train / test by
/// Bernoulli(test_frac), deterministic in `seed` — the tensor analogue
/// of [`split_train_test`].  Dimensions are preserved on both sides.
pub fn split_tensor_train_test(
    t: &crate::sparse::SparseTensor,
    test_frac: f64,
    seed: u64,
) -> (crate::sparse::SparseTensor, crate::sparse::SparseTensor) {
    assert!((0.0..1.0).contains(&test_frac));
    let nmodes = t.nmodes();
    let mut rng = crate::rng::Rng::from_parts(seed, 0x5917);
    let (mut tr_flat, mut tr_vals) = (Vec::new(), Vec::new());
    let (mut te_flat, mut te_vals) = (Vec::new(), Vec::new());
    for (e, v) in t.entry_ids() {
        let (flat, vals) = if rng.next_f64() < test_frac {
            (&mut te_flat, &mut te_vals)
        } else {
            (&mut tr_flat, &mut tr_vals)
        };
        for m in 0..nmodes {
            flat.push(t.coord(m, e));
        }
        vals.push(v);
    }
    (
        crate::sparse::SparseTensor::from_flat(t.dims().to_vec(), &tr_flat, &tr_vals),
        crate::sparse::SparseTensor::from_flat(t.dims().to_vec(), &te_flat, &te_vals),
    )
}

/// Split a sparse matrix's entries into train / test by Bernoulli(test_frac).
/// Deterministic in `seed`; the split keeps matrix dimensions.
pub fn split_train_test(
    m: &SparseMatrix,
    test_frac: f64,
    seed: u64,
) -> (SparseMatrix, SparseMatrix) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = crate::rng::Rng::from_parts(seed, 0x5917);
    let mut train = Vec::new();
    let mut test = Vec::new();
    for (r, c, v) in m.triplets() {
        if rng.next_f64() < test_frac {
            test.push((r, c, v));
        } else {
            train.push((r, c, v));
        }
    }
    (
        SparseMatrix::from_triplets(m.nrows(), m.ncols(), train),
        SparseMatrix::from_triplets(m.nrows(), m.ncols(), test),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_sparse() -> SparseMatrix {
        SparseMatrix::from_triplets(3, 3, vec![(0, 0, 1.0), (1, 1, 2.0), (2, 2, 3.0), (0, 2, 4.0)])
    }

    #[test]
    fn matrix_config_counts() {
        let s = sample_sparse();
        assert_eq!(MatrixConfig::SparseUnknown(s.clone()).nobs(), 4);
        assert_eq!(MatrixConfig::SparseFull(s.clone()).nobs(), 9);
        assert!(!MatrixConfig::SparseUnknown(s.clone()).fully_observed());
        assert!(MatrixConfig::SparseFull(s.clone()).fully_observed());
        let d = Mat::zeros(2, 5);
        let mc = MatrixConfig::Dense(d);
        assert_eq!(mc.nobs(), 10);
        assert_eq!((mc.nrows(), mc.ncols()), (2, 5));
    }

    #[test]
    fn mean_semantics_differ_by_kind() {
        let s = sample_sparse(); // values 1,2,3,4 over 9 cells
        let unknown_mean = MatrixConfig::SparseUnknown(s.clone()).mean();
        let full_mean = MatrixConfig::SparseFull(s).mean();
        assert!((unknown_mean - 2.5).abs() < 1e-12);
        assert!((full_mean - 10.0 / 9.0).abs() < 1e-12);
    }

    #[test]
    fn side_info_dense_sparse_agree() {
        let d = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 2.0, 3.0, 4.0]);
        let s = SparseMatrix::from_triplets(
            3,
            2,
            vec![(0, 0, 1.0), (1, 1, 2.0), (2, 0, 3.0), (2, 1, 4.0)],
        );
        let sd = SideInfo::Dense(d);
        let ss = SideInfo::Sparse(s);
        let x = [1.0, -1.0];
        assert_eq!(sd.matvec(&x), ss.matvec(&x));
        let y = [1.0, 2.0, 3.0];
        assert_eq!(sd.matvec_t(&y), ss.matvec_t(&y));
        let mut r1 = [0.0; 2];
        let mut r2 = [0.0; 2];
        sd.row_dense(2, &mut r1);
        ss.row_dense(2, &mut r2);
        assert_eq!(r1, r2);
    }

    #[test]
    fn split_is_deterministic_and_partitions() {
        let m = crate::data::movielens_like(50, 40, 600, 0.0, 1).0;
        let (tr1, te1) = split_train_test(&m, 0.25, 9);
        let (tr2, te2) = split_train_test(&m, 0.25, 9);
        assert_eq!(tr1.nnz(), tr2.nnz());
        assert_eq!(te1.nnz(), te2.nnz());
        assert_eq!(tr1.nnz() + te1.nnz(), m.nnz());
        // roughly 25%
        let frac = te1.nnz() as f64 / m.nnz() as f64;
        assert!((frac - 0.25).abs() < 0.08, "frac {frac}");
        // different seeds differ
        let (tr3, _) = split_train_test(&m, 0.25, 10);
        assert_ne!(
            tr1.triplets().collect::<Vec<_>>(),
            tr3.triplets().collect::<Vec<_>>()
        );
    }

    #[test]
    fn testset_from_sparse() {
        let t = TestSet::from_sparse(&sample_sparse());
        assert_eq!(t.len(), 4);
        assert_eq!(t.rows.len(), t.cols.len());
    }
}
