//! Noise models (Table 1, "Noise Model"): fixed-precision Gaussian,
//! adaptive-precision Gaussian (precision resampled from its Gamma
//! conditional each iteration) and probit noise for binary data
//! (truncated-normal data augmentation, Albert & Chib 1993).

use crate::rng::Rng;

/// User-facing noise configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum NoiseConfig {
    /// Gaussian with fixed precision α.
    Fixed { precision: f64 },
    /// Gaussian with precision resampled from Gamma(shape0 + n/2,
    /// rate0 + SSE/2), capped at `sn_max` × the signal precision.
    Adaptive { sn_init: f64, sn_max: f64 },
    /// Probit link for ±1 data via truncated-normal augmentation.
    Probit,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        NoiseConfig::Fixed { precision: 5.0 }
    }
}

/// Runtime state of a noise model for one data view.
#[derive(Debug, Clone)]
pub enum NoiseModel {
    Fixed { alpha: f64 },
    Adaptive { alpha: f64, sn_max: f64, var_total: f64 },
    Probit,
}

impl NoiseModel {
    pub fn new(cfg: &NoiseConfig, data_variance: f64) -> NoiseModel {
        match *cfg {
            NoiseConfig::Fixed { precision } => NoiseModel::Fixed { alpha: precision },
            NoiseConfig::Adaptive { sn_init, sn_max } => NoiseModel::Adaptive {
                // α = signal-to-noise  / data variance (SMURFF's init rule)
                alpha: sn_init.max(1e-3) / data_variance.max(1e-12),
                sn_max,
                var_total: data_variance,
            },
            NoiseConfig::Probit => NoiseModel::Probit,
        }
    }

    /// The current likelihood precision used by the row conditionals.
    pub fn alpha(&self) -> f64 {
        match self {
            NoiseModel::Fixed { alpha } => *alpha,
            NoiseModel::Adaptive { alpha, .. } => *alpha,
            // augmented probit model has unit precision by construction
            NoiseModel::Probit => 1.0,
        }
    }

    pub fn is_probit(&self) -> bool {
        matches!(self, NoiseModel::Probit)
    }

    /// End-of-iteration update.  `sse` is the sum of squared residuals
    /// over the `nobs` observed cells.  Fixed/probit are no-ops.
    pub fn update(&mut self, sse: f64, nobs: usize, rng: &mut Rng) {
        if let NoiseModel::Adaptive { alpha, sn_max, var_total } = self {
            // conjugate Gamma posterior with a weak Gamma(2, 2/precision0) prior
            let prior_shape = 2.0;
            let prior_rate = 2.0 * *var_total; // rate = shape/mean, mean = 1/var
            let shape = prior_shape + 0.5 * nobs as f64;
            let rate = prior_rate + 0.5 * sse;
            // Gamma(shape, scale = 1/rate)
            let a = rng.gamma(shape, 1.0 / rate);
            let cap = *sn_max / var_total.max(1e-12);
            *alpha = a.min(cap).max(1e-6);
        }
    }

    /// Restore a snapshotted precision (store resume).  Fixed and probit
    /// noise carry no evolving state, so this only touches Adaptive.
    pub fn restore_alpha(&mut self, a: f64) {
        if let NoiseModel::Adaptive { alpha, .. } = self {
            *alpha = a;
        }
    }

    /// Probit augmentation: sample the latent z given the prediction m
    /// and the binary label (+1 / -1 by sign of the stored value).
    pub fn augment_probit(pred: f64, label: f64, rng: &mut Rng) -> f64 {
        if label > 0.0 {
            pred + rng.truncated_normal_lower(-pred)
        } else {
            pred + rng.truncated_normal_upper(-pred)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_alpha_is_constant() {
        let mut m = NoiseModel::new(&NoiseConfig::Fixed { precision: 3.0 }, 1.0);
        assert_eq!(m.alpha(), 3.0);
        let mut rng = Rng::new(0);
        m.update(100.0, 50, &mut rng);
        assert_eq!(m.alpha(), 3.0);
    }

    #[test]
    fn adaptive_tracks_residuals() {
        // With a huge SSE the precision must come out small; with a tiny
        // SSE it must grow (up to the cap).
        let mut rng = Rng::new(1);
        let mut hi = NoiseModel::new(&NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 100.0 }, 1.0);
        let mut lo = hi.clone();
        hi.update(10_000.0, 1000, &mut rng); // noisy fit -> small alpha
        lo.update(1.0, 1000, &mut rng); // tight fit -> large alpha
        assert!(hi.alpha() < 1.0, "hi {}", hi.alpha());
        assert!(lo.alpha() > 10.0, "lo {}", lo.alpha());
    }

    #[test]
    fn adaptive_respects_cap() {
        let mut rng = Rng::new(2);
        let mut m = NoiseModel::new(&NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 }, 2.0);
        m.update(1e-9, 10_000, &mut rng);
        assert!(m.alpha() <= 10.0 / 2.0 + 1e-9, "alpha {}", m.alpha());
    }

    #[test]
    fn adaptive_posterior_mean_is_reasonable() {
        // SSE = nobs * sigma^2 with sigma^2 = 0.25 -> alpha ≈ 4
        let mut rng = Rng::new(3);
        let mut acc = 0.0;
        let n = 500;
        for _ in 0..n {
            let mut m =
                NoiseModel::new(&NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 1e6 }, 1.0);
            m.update(0.25 * 10_000.0, 10_000, &mut rng);
            acc += m.alpha();
        }
        let mean = acc / n as f64;
        assert!((mean - 4.0).abs() < 0.2, "mean {mean}");
    }

    #[test]
    fn restore_alpha_only_touches_adaptive() {
        let mut a = NoiseModel::new(&NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 }, 1.0);
        a.restore_alpha(3.75);
        assert_eq!(a.alpha(), 3.75);
        let mut f = NoiseModel::new(&NoiseConfig::Fixed { precision: 2.0 }, 1.0);
        f.restore_alpha(9.0);
        assert_eq!(f.alpha(), 2.0);
        let mut p = NoiseModel::new(&NoiseConfig::Probit, 1.0);
        p.restore_alpha(9.0);
        assert_eq!(p.alpha(), 1.0);
    }

    #[test]
    fn probit_alpha_is_one_and_augmentation_respects_sign() {
        let m = NoiseModel::new(&NoiseConfig::Probit, 1.0);
        assert_eq!(m.alpha(), 1.0);
        assert!(m.is_probit());
        let mut rng = Rng::new(4);
        for _ in 0..500 {
            let z = NoiseModel::augment_probit(0.3, 1.0, &mut rng);
            assert!(z >= 0.0);
            let z = NoiseModel::augment_probit(0.3, -1.0, &mut rng);
            assert!(z <= 0.0);
        }
    }

    #[test]
    fn probit_augmentation_mean_shifts_with_prediction() {
        // For strongly positive prediction and +1 label, z ≈ pred
        let mut rng = Rng::new(5);
        let n = 20_000;
        let mean: f64 = (0..n)
            .map(|_| NoiseModel::augment_probit(2.5, 1.0, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean - 2.52).abs() < 0.05, "mean {mean}"); // E[TN(2.5,1,>0)] ≈ 2.52
    }
}
