//! Unified observability substrate: one process-wide metrics registry
//! (counters, gauges, fixed-bucket histograms) plus lightweight span
//! tracing ([`trace`]) — the single counter system every layer reports
//! through (train sweeps, the distributed comm substrate, the serve
//! front-end, the bench harness).
//!
//! Design rules, in order:
//!
//! 1. **Sample-preserving.**  No instrumentation point may touch an RNG
//!    stream, reorder float summation, or change a scheduling decision.
//!    Everything here is passive: relaxed atomics and wall-clock reads.
//!    `session::tests::tracing_preserves_samples_bit_identically` holds
//!    this invariant down to the bit.
//! 2. **Lock-cheap.**  Handle lookup ([`counter`] / [`gauge`] /
//!    [`histogram`]) takes a registry mutex and is meant for setup code
//!    or per-iteration granularity; hot paths cache the returned `Arc`
//!    and then pay only relaxed atomic ops per update.  With the
//!    registry disabled ([`set_enabled`]`(false)`) a histogram
//!    observation or span is a single relaxed load — counters and
//!    gauges stay live (they *are* just a relaxed `fetch_add`).
//! 3. **No new dependencies.**  Exposition is hand-rolled Prometheus
//!    text ([`render_prometheus`]); traces serialize through
//!    [`crate::util::json`] as Chrome trace-event JSON.
//!
//! ## Naming
//!
//! Metric names follow Prometheus conventions:
//! `smurff_<layer>_<what>[_total]`, with labels inline in the name
//! (`smurff_dist_bytes_sent_total{strategy="sync",rank="0"}`).  The
//! exposition groups series of one family under a single `# TYPE` line.

pub mod trace;

pub use trace::{
    chrome_trace_json, span, span_dyn, trace_clear, trace_counter, trace_enable, trace_enabled,
    Span,
};

use crate::util::JsonValue;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Master switch for the *optional* collection paths (histogram
/// observations, span recording, per-sweep registry folds).  Counters
/// and gauges are unconditional — a relaxed `fetch_add` is already the
/// floor this flag exists to guarantee.
static ENABLED: AtomicBool = AtomicBool::new(true);

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

// ---------------------------------------------------------- primitives

/// Monotone event counter (u64, relaxed).
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-value / accumulating gauge (f64 stored as bits, relaxed).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => return,
                Err(c) => cur = c,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Fixed-bucket histogram with Prometheus `le` (≤ bound) semantics:
/// `buckets[i]` counts observations `v <= bounds[i]`, the final slot is
/// the +Inf overflow.  Quantiles are estimated by linear interpolation
/// inside the covering bucket — the classic fixed-bucket estimator, so
/// the error is bounded by one bucket width.
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_bits: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0),
        }
    }

    /// Record one observation.  A no-op while the registry is disabled
    /// (the documented cheap path: one relaxed load).
    pub fn observe(&self, v: f64) {
        if !enabled() {
            return;
        }
        let idx = self.bounds.partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum_bits.load(Ordering::Relaxed))
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Per-bucket (non-cumulative) counts; last entry is the overflow.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Estimated `q`-quantile (`0.0..=1.0`), NaN when empty.  Values in
    /// the overflow bucket clamp to the largest bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = cum + c;
            if (next as f64) >= target {
                if i == self.bounds.len() {
                    return self.bounds[self.bounds.len() - 1];
                }
                let lo = if i == 0 { 0.0f64.min(self.bounds[0]) } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let frac = (target - cum as f64) / c as f64;
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
            cum = next;
        }
        self.bounds[self.bounds.len() - 1]
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_bits.store(0, Ordering::Relaxed);
    }
}

// ------------------------------------------------------ shared bounds

/// Latency bounds in seconds: 10µs … 10s, roughly ×2.5 steps.
pub const LATENCY_BOUNDS_S: &[f64] = &[
    1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25,
    0.5, 1.0, 2.5, 5.0, 10.0,
];

/// Size/count bounds: powers of two up to 64Ki.
pub const SIZE_BOUNDS: &[f64] = &[
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0,
    16384.0, 32768.0, 65536.0,
];

// ------------------------------------------------------------ registry

/// The process-wide metric registry: three name-sorted maps of shared
/// handles.  Lookup locks a mutex; updates through the handles do not.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

pub fn registry() -> &'static Registry {
    static R: OnceLock<Registry> = OnceLock::new();
    R.get_or_init(Registry::default)
}

/// Get (registering on first use) the counter called `name`.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut m = registry().counters.lock().unwrap();
    m.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new())).clone()
}

/// Get (registering on first use) the gauge called `name`.
pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut m = registry().gauges.lock().unwrap();
    m.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new())).clone()
}

/// Get (registering on first use) the histogram called `name`.  The
/// first registration pins the bucket bounds; later callers receive the
/// existing histogram (bounds argument ignored, asserted in debug).
pub fn histogram(name: &str, bounds: &[f64]) -> Arc<Histogram> {
    let mut m = registry().histograms.lock().unwrap();
    let h = m.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new(bounds))).clone();
    debug_assert_eq!(h.bounds(), bounds, "histogram '{name}' re-registered with other bounds");
    h
}

/// One-shot counter bump for cold paths (per-sweep / per-iteration
/// granularity — takes the registry lock).
pub fn counter_add(name: &str, v: u64) {
    counter(name).add(v);
}

/// One-shot gauge store for cold paths.
pub fn gauge_set(name: &str, v: f64) {
    gauge(name).set(v);
}

/// One-shot gauge accumulate for cold paths.
pub fn gauge_add(name: &str, v: f64) {
    gauge(name).add(v);
}

/// Zero every metric (tests / bench isolation).  Handles stay valid.
pub fn reset() {
    for c in registry().counters.lock().unwrap().values() {
        c.reset();
    }
    for g in registry().gauges.lock().unwrap().values() {
        g.reset();
    }
    for h in registry().histograms.lock().unwrap().values() {
        h.reset();
    }
}

// ----------------------------------------------------- comm accounting

/// Per-instance byte/time meter for the distributed comm substrate —
/// the registry-primitive replacement for the plain-field accounting
/// `distributed::comm` used to carry (one counter system, satellite of
/// ISSUE 6).  Instances are not registered globally: a `Comm` is
/// per-node per-run, and [`crate::distributed::DistributedSession`]
/// folds the totals into labelled registry metrics at run end.
#[derive(Default)]
pub struct CommMeter {
    bytes: Counter,
    nanos: Counter,
}

impl CommMeter {
    pub fn new() -> CommMeter {
        CommMeter::default()
    }

    pub fn add_bytes(&self, b: u64) {
        self.bytes.add(b);
    }

    pub fn add_seconds(&self, s: f64) {
        self.nanos.add((s * 1e9) as u64);
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.get()
    }

    pub fn seconds(&self) -> f64 {
        self.nanos.get() as f64 * 1e-9
    }
}

// ---------------------------------------------------------- exposition

/// Format an f64 the Prometheus way (`+Inf`, integers without `.0`).
fn fmt_val(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// `name{a="b"}` → (`name`, `a="b"`); unlabelled names return ("", ..).
fn split_labels(name: &str) -> (&str, &str) {
    match name.find('{') {
        Some(i) => (&name[..i], name[i + 1..].trim_end_matches('}')),
        None => (name, ""),
    }
}

/// Append a series line, merging existing labels with `extra` labels.
fn push_series(out: &mut String, base: &str, labels: &str, extra: &str, value: &str) {
    out.push_str(base);
    let joined = match (labels.is_empty(), extra.is_empty()) {
        (true, true) => String::new(),
        (true, false) => extra.to_string(),
        (false, true) => labels.to_string(),
        (false, false) => format!("{labels},{extra}"),
    };
    if !joined.is_empty() {
        out.push('{');
        out.push_str(&joined);
        out.push('}');
    }
    out.push(' ');
    out.push_str(value);
    out.push('\n');
}

/// Render every registered metric as Prometheus text exposition
/// (`text/plain; version=0.0.4`): counters, gauges, then histograms
/// with cumulative `_bucket{le=…}` series plus `_sum` / `_count`.
pub fn render_prometheus() -> String {
    let mut out = String::new();
    let mut last_family = String::new();
    let mut type_line = |out: &mut String, base: &str, kind: &str| {
        if base != last_family {
            out.push_str(&format!("# TYPE {base} {kind}\n"));
            last_family = base.to_string();
        }
    };
    for (name, c) in registry().counters.lock().unwrap().iter() {
        let (base, labels) = split_labels(name);
        type_line(&mut out, base, "counter");
        push_series(&mut out, base, labels, "", &c.get().to_string());
    }
    for (name, g) in registry().gauges.lock().unwrap().iter() {
        let (base, labels) = split_labels(name);
        type_line(&mut out, base, "gauge");
        push_series(&mut out, base, labels, "", &fmt_val(g.get()));
    }
    for (name, h) in registry().histograms.lock().unwrap().iter() {
        let (base, labels) = split_labels(name);
        type_line(&mut out, base, "histogram");
        let counts = h.bucket_counts();
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cum += c;
            let le = if i == h.bounds().len() { f64::INFINITY } else { h.bounds()[i] };
            push_series(
                &mut out,
                &format!("{base}_bucket"),
                labels,
                &format!("le=\"{}\"", fmt_val(le)),
                &cum.to_string(),
            );
        }
        push_series(&mut out, &format!("{base}_sum"), labels, "", &fmt_val(h.sum()));
        push_series(&mut out, &format!("{base}_count"), labels, "", &cum.to_string());
    }
    out
}

/// Snapshot every metric as JSON — the phase-breakdown section the
/// bench harness embeds into its `--json` reports.  Histograms carry
/// count/sum and the p50/p90/p99 estimates.
pub fn snapshot_json() -> JsonValue {
    let counters: BTreeMap<String, JsonValue> = registry()
        .counters
        .lock()
        .unwrap()
        .iter()
        .map(|(k, c)| (k.clone(), JsonValue::num(c.get() as f64)))
        .collect();
    let gauges: BTreeMap<String, JsonValue> = registry()
        .gauges
        .lock()
        .unwrap()
        .iter()
        .map(|(k, g)| (k.clone(), JsonValue::num(g.get())))
        .collect();
    let histograms: BTreeMap<String, JsonValue> = registry()
        .histograms
        .lock()
        .unwrap()
        .iter()
        .map(|(k, h)| {
            // empty histograms have NaN quantiles — emit null, not an
            // unparseable bare NaN token
            let q = |q: f64| {
                let v = h.quantile(q);
                if v.is_finite() { JsonValue::num(v) } else { JsonValue::Null }
            };
            (
                k.clone(),
                JsonValue::obj(vec![
                    ("count", JsonValue::num(h.count() as f64)),
                    ("sum", JsonValue::num(h.sum())),
                    ("p50", q(0.50)),
                    ("p90", q(0.90)),
                    ("p99", q(0.99)),
                ]),
            )
        })
        .collect();
    JsonValue::Object(
        [
            ("counters".to_string(), JsonValue::Object(counters)),
            ("gauges".to_string(), JsonValue::Object(gauges)),
            ("histograms".to_string(), JsonValue::Object(histograms)),
        ]
        .into_iter()
        .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests that observe histograms (or flip [`set_enabled`]) must not
    /// interleave with the disabled-flag test: the flag is process-wide
    /// and `cargo test` runs threads in parallel.
    fn flag_lock() -> std::sync::MutexGuard<'static, ()> {
        static L: Mutex<()> = Mutex::new(());
        L.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn counter_and_gauge_basics() {
        let c = counter("test_obs_basics_total");
        c.add(2);
        c.add(3);
        assert_eq!(c.get(), 5);
        // same name -> same handle
        counter("test_obs_basics_total").add(1);
        assert_eq!(c.get(), 6);

        let g = gauge("test_obs_basics_gauge");
        g.set(1.5);
        g.add(0.25);
        assert_eq!(g.get(), 1.75);
        g.set(-3.0);
        assert_eq!(g.get(), -3.0);
    }

    #[test]
    fn histogram_bucket_boundaries_are_le() {
        // Prometheus `le` semantics: a value exactly on a bound lands in
        // that bound's bucket, the next representable value above it in
        // the following one; above the last bound -> overflow.
        let _g = flag_lock();
        let h = Histogram::new(&[1.0, 2.0, 5.0]);
        h.observe(0.0);
        h.observe(1.0);
        h.observe(f64::from_bits(1.0f64.to_bits() + 1));
        h.observe(2.0);
        h.observe(5.0);
        h.observe(5.0 + 1e-12);
        h.observe(1e12);
        assert_eq!(h.bucket_counts(), vec![2, 2, 1, 2]);
        assert_eq!(h.count(), 7);
        assert!((h.sum() - (0.0 + 1.0 + 1.0 + 2.0 + 5.0 + 5.0 + 1e12)).abs() < 1.0);
    }

    #[test]
    fn quantile_estimates_track_exact_quantiles() {
        // uniform 1..=1000 into 20 linear buckets: the interpolated
        // estimate must sit within one bucket width of the exact value
        let _g = flag_lock();
        let bounds: Vec<f64> = (1..=20).map(|i| (i * 50) as f64).collect();
        let h = Histogram::new(&bounds);
        for v in 1..=1000 {
            h.observe(v as f64);
        }
        for (q, exact) in [(0.5, 500.0), (0.9, 900.0), (0.99, 990.0)] {
            let est = h.quantile(q);
            assert!(
                (est - exact).abs() <= 50.0,
                "q={q}: estimate {est} vs exact {exact}"
            );
        }
        // degenerate cases
        assert!(Histogram::new(&[1.0]).quantile(0.5).is_nan());
        let h = Histogram::new(&[1.0, 2.0]);
        h.observe(100.0); // overflow-only population clamps to the top bound
        assert_eq!(h.quantile(0.5), 2.0);
    }

    #[test]
    fn concurrent_updates_from_the_threadpool_are_exact() {
        let _g = flag_lock();
        let pool = crate::coordinator::ThreadPool::new(4);
        let c = counter("test_obs_pool_total");
        let h = histogram("test_obs_pool_hist", &[10.0, 100.0, 1000.0]);
        let before = c.get();
        let hbefore = h.count();
        pool.parallel_for(10_000, 16, |t| {
            c.add(1);
            h.observe((t % 2000) as f64);
        });
        assert_eq!(c.get() - before, 10_000);
        assert_eq!(h.count() - hbefore, 10_000);
        let counts = h.bucket_counts();
        assert_eq!(counts.iter().sum::<u64>(), h.count());
    }

    #[test]
    fn disabled_registry_skips_histograms_but_keeps_counters() {
        let _g = flag_lock();
        let c = counter("test_obs_disabled_total");
        let h = histogram("test_obs_disabled_hist", &[1.0, 2.0]);
        set_enabled(false);
        let hc = h.count();
        h.observe(1.0);
        c.add(1);
        assert_eq!(h.count(), hc, "disabled histogram must not record");
        set_enabled(true);
        h.observe(1.0);
        assert_eq!(h.count(), hc + 1);
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let _g = flag_lock();
        counter("test_obs_expo_total{kind=\"a\"}").add(3);
        counter("test_obs_expo_total{kind=\"b\"}").add(4);
        gauge("test_obs_expo_depth").set(2.0);
        let h = histogram("test_obs_expo_lat", &[0.5, 1.0]);
        h.observe(0.25);
        h.observe(0.75);
        h.observe(9.0);
        let text = render_prometheus();
        assert!(text.contains("# TYPE test_obs_expo_total counter"));
        // one TYPE line per family, both labelled series present
        assert_eq!(text.matches("# TYPE test_obs_expo_total counter").count(), 1);
        assert!(text.contains("test_obs_expo_total{kind=\"a\"} 3"));
        assert!(text.contains("test_obs_expo_total{kind=\"b\"} 4"));
        assert!(text.contains("# TYPE test_obs_expo_depth gauge"));
        assert!(text.contains("test_obs_expo_depth 2"));
        assert!(text.contains("# TYPE test_obs_expo_lat histogram"));
        assert!(text.contains("test_obs_expo_lat_bucket{le=\"0.5\"} 1"));
        assert!(text.contains("test_obs_expo_lat_bucket{le=\"1\"} 2"));
        assert!(text.contains("test_obs_expo_lat_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("test_obs_expo_lat_count 3"));
    }

    #[test]
    fn snapshot_json_carries_quantiles() {
        let _g = flag_lock();
        let h = histogram("test_obs_snap_hist", &[1.0, 2.0, 4.0]);
        for _ in 0..10 {
            h.observe(1.5);
        }
        let snap = snapshot_json();
        let hj = snap.get("histograms").unwrap().get("test_obs_snap_hist").unwrap();
        assert!(hj.get("count").unwrap().as_f64().unwrap() >= 10.0);
        let p50 = hj.get("p50").unwrap().as_f64().unwrap();
        assert!(p50 > 1.0 && p50 <= 2.0, "p50 {p50} must interpolate inside (1,2]");
    }

    #[test]
    fn comm_meter_accumulates() {
        let m = CommMeter::new();
        m.add_bytes(100);
        m.add_bytes(28);
        m.add_seconds(0.5);
        m.add_seconds(0.25);
        assert_eq!(m.bytes(), 128);
        assert!((m.seconds() - 0.75).abs() < 1e-6);
    }
}
