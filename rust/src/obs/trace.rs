//! Lightweight span tracing: scoped phase timers that serialize as
//! Chrome trace-event JSON (`chrome://tracing` / Perfetto loadable).
//!
//! Tracing is **off by default** and costs one relaxed load per
//! instrumentation point while off — no allocation, no clock read, no
//! lock.  When enabled ([`trace_enable`]), a [`Span`] guard records a
//! complete ("ph":"X") event on drop with microsecond timestamps
//! relative to the first event, and [`trace_counter`] records counter
//! ("ph":"C") samples (e.g. RMSE per Gibbs iteration).  The buffer is
//! bounded: past [`MAX_EVENTS`] new events are counted as dropped
//! rather than grown, so a forgotten `--trace` cannot OOM a long run.
//!
//! The recording path takes a single process-wide mutex per event.
//! That is deliberate: spans here mark *phases* (a sweep, a Cholesky
//! pass over a mode, a serve batch), not per-row work, so contention is
//! negligible — and the sample-preserving invariant matters more than
//! nanoseconds (see `obs` module docs).

use crate::util::JsonValue;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Bounded trace buffer size; ~100 bytes/event keeps worst case <100MB.
pub const MAX_EVENTS: usize = 1 << 20;

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);

enum Event {
    /// Complete duration event ("ph":"X").
    Span { name: String, cat: &'static str, ts_us: u64, dur_us: u64, tid: u64 },
    /// Counter sample ("ph":"C").
    Counter { name: String, ts_us: u64, value: f64 },
}

#[derive(Default)]
struct TraceBuf {
    events: Vec<Event>,
    /// Small stable ints per OS thread for the "tid" field.
    tids: HashMap<std::thread::ThreadId, u64>,
}

fn buf() -> &'static Mutex<TraceBuf> {
    static B: OnceLock<Mutex<TraceBuf>> = OnceLock::new();
    B.get_or_init(|| Mutex::new(TraceBuf::default()))
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// Turn trace recording on or off (process-wide).
pub fn trace_enable(on: bool) {
    if on {
        let _ = epoch(); // pin t=0 before the first span
    }
    TRACE_ON.store(on, Ordering::Relaxed);
}

pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Discard all buffered events (tests / between bench cases).
pub fn trace_clear() {
    let mut b = buf().lock().unwrap();
    b.events.clear();
    DROPPED.store(0, Ordering::Relaxed);
}

fn push(ev: Event) {
    let mut b = buf().lock().unwrap();
    if b.events.len() >= MAX_EVENTS {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        // Mirror into the registry so buffer saturation is scrapeable,
        // not only visible inside the trace file (ISSUE 7 satellite).
        crate::obs::counter_add("smurff_trace_dropped_total", 1);
        return;
    }
    b.events.push(ev);
}

/// RAII phase timer: records a complete event from construction to drop.
/// While tracing is disabled, construction is a single relaxed load and
/// the guard is inert.
pub struct Span(Option<SpanStart>);

struct SpanStart {
    name: String,
    cat: &'static str,
    start_us: u64,
}

/// Open a span named `name` in category `cat` (the chrome trace "cat"
/// field — use one per layer: "gibbs", "sweep", "serve", "dist").
pub fn span(cat: &'static str, name: &str) -> Span {
    if !trace_enabled() {
        return Span(None);
    }
    Span(Some(SpanStart { name: name.to_string(), cat, start_us: now_us() }))
}

/// Like [`span`] but the name is built lazily, so callers can use
/// `format!` without paying the allocation when tracing is off.
pub fn span_dyn(cat: &'static str, name: impl FnOnce() -> String) -> Span {
    if !trace_enabled() {
        return Span(None);
    }
    Span(Some(SpanStart { name: name(), cat, start_us: now_us() }))
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            let end = now_us();
            let tid = {
                let mut b = buf().lock().unwrap();
                let next = b.tids.len() as u64 + 1;
                *b.tids.entry(std::thread::current().id()).or_insert(next)
            };
            push(Event::Span {
                name: s.name,
                cat: s.cat,
                ts_us: s.start_us,
                dur_us: end.saturating_sub(s.start_us),
                tid,
            });
        }
    }
}

/// Record a counter sample (rendered as a stacked chart by the trace
/// viewer) — e.g. `trace_counter("rmse", r)` once per iteration.
pub fn trace_counter(name: &str, value: f64) {
    if !trace_enabled() {
        return;
    }
    push(Event::Counter { name: name.to_string(), ts_us: now_us(), value });
}

/// Number of buffered events (diagnostics/tests).
pub fn event_count() -> usize {
    buf().lock().unwrap().events.len()
}

/// Serializes tests that toggle the process-wide trace flag —
/// `cargo test` runs threads in parallel, and one test flipping the
/// flag mid-span of another would drop that other test's events.
#[cfg(test)]
pub(crate) fn test_flag_lock() -> std::sync::MutexGuard<'static, ()> {
    static L: Mutex<()> = Mutex::new(());
    L.lock().unwrap_or_else(|e| e.into_inner())
}

/// Serialize the buffer in Chrome trace-event format (the object form:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}`), loadable in
/// chrome://tracing or https://ui.perfetto.dev.
pub fn chrome_trace_json() -> JsonValue {
    let b = buf().lock().unwrap();
    let events: Vec<JsonValue> = b
        .events
        .iter()
        .map(|ev| match ev {
            Event::Span { name, cat, ts_us, dur_us, tid } => JsonValue::obj(vec![
                ("name", JsonValue::str(name)),
                ("cat", JsonValue::str(cat)),
                ("ph", JsonValue::str("X")),
                ("ts", JsonValue::num(*ts_us as f64)),
                ("dur", JsonValue::num(*dur_us as f64)),
                ("pid", JsonValue::num(1.0)),
                ("tid", JsonValue::num(*tid as f64)),
            ]),
            Event::Counter { name, ts_us, value } => JsonValue::obj(vec![
                ("name", JsonValue::str(name)),
                ("ph", JsonValue::str("C")),
                ("ts", JsonValue::num(*ts_us as f64)),
                ("pid", JsonValue::num(1.0)),
                ("args", JsonValue::obj(vec![("value", JsonValue::num(*value))])),
            ]),
        })
        .collect();
    JsonValue::obj(vec![
        ("traceEvents", JsonValue::Array(events)),
        ("displayTimeUnit", JsonValue::str("ms")),
        ("droppedEvents", JsonValue::num(DROPPED.load(Ordering::Relaxed) as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = test_flag_lock();
        trace_enable(false);
        let n = event_count();
        {
            let _s = span("test", "should_not_appear");
        }
        trace_counter("test_ctr", 1.0);
        assert_eq!(event_count(), n);
    }

    #[test]
    fn spans_round_trip_through_chrome_json() {
        let _g = test_flag_lock();
        trace_enable(true);
        {
            let _s = span("testcat", "test_phase_a");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        {
            let _s = span_dyn("testcat", || format!("test_phase_{}", 2));
        }
        trace_counter("test_rmse", 0.5);
        trace_enable(false);

        let j = chrome_trace_json();
        // must survive a parse round-trip of our own JSON layer
        let reparsed = JsonValue::parse(&j.to_string_pretty()).unwrap();
        let evs = reparsed.get("traceEvents").unwrap().as_array().unwrap();
        let names: Vec<&str> =
            evs.iter().filter_map(|e| e.get("name").and_then(|n| n.as_str())).collect();
        assert!(names.contains(&"test_phase_a"));
        assert!(names.contains(&"test_phase_2"));
        assert!(names.contains(&"test_rmse"));
        let a = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("test_phase_a"))
            .unwrap();
        assert_eq!(a.get("ph").unwrap().as_str().unwrap(), "X");
        assert!(a.get("dur").unwrap().as_f64().unwrap() >= 1000.0, "slept 1ms -> dur >= 1000us");
        assert!(a.get("ts").is_some() && a.get("tid").is_some() && a.get("pid").is_some());
        let c = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("test_rmse"))
            .unwrap();
        assert_eq!(c.get("ph").unwrap().as_str().unwrap(), "C");
        assert_eq!(c.get("args").unwrap().get("value").unwrap().as_f64().unwrap(), 0.5);
    }
}
