//! The immutable in-memory model behind serving (ISSUE 5 tentpole).
//!
//! A [`ServingModel`] presents every posterior sample's factors as
//! contiguous sample-major *panels* ([`FactorPanel`]) handing out
//! borrowed [`MatRef`]s:
//!
//! * on a **packed** store (layout v3) the panels are zero-copy windows
//!   into the mmap'd `packed/*.pack` files — opening the model reads no
//!   factor data at all;
//! * on a snapshot-dir store the samples are loaded once into owned
//!   buffers with the identical sample-major layout.
//!
//! Either way the scoring engine in [`crate::predict`] sees the same
//! borrowed panels, so both representations serve bit-identical
//! predictions (tested), and the model is shared across threads as an
//! `Arc<ServingModel>` that a hot-reload watcher can atomically swap
//! while in-flight requests finish on the old sample set.

use crate::linalg::MatRef;
use crate::store::packed::PackFile;
use crate::store::{ModelStore, StoreMeta};
use std::path::Path;
use std::sync::Arc;

enum PanelStorage {
    /// Borrowed zero-copy window into a pack file's sample blocks
    /// (`offset` = f64 position of this factor inside each block).
    Packed { file: Arc<PackFile>, offset: usize },
    /// Owned sample-major buffer built from a snapshot-dir store.
    Owned(Vec<f64>),
}

/// One factor matrix (`rows × cols`) across every posterior sample,
/// sample-major and contiguous per sample.
pub struct FactorPanel {
    rows: usize,
    cols: usize,
    storage: PanelStorage,
}

impl FactorPanel {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Sample `s`'s factor matrix as a borrowed view.
    #[inline]
    pub fn sample(&self, s: usize) -> MatRef<'_> {
        let len = self.rows * self.cols;
        let data = match &self.storage {
            PanelStorage::Packed { file, offset } => &file.block(s)[*offset..*offset + len],
            PanelStorage::Owned(buf) => &buf[s * len..(s + 1) * len],
        };
        MatRef::new(self.rows, self.cols, data)
    }
}

struct LinkPanels {
    /// β, F × K per sample
    beta: FactorPanel,
    /// μ, 1 × K per sample
    mu: FactorPanel,
}

/// Immutable posterior model ready to serve: manifest metadata plus one
/// [`FactorPanel`] per factor matrix (shared mode-0 `u`, the flat `vs`
/// list in `Snapshot::vs` order, and the optional Macau link model).
pub struct ServingModel {
    meta: StoreMeta,
    nsamples: usize,
    iterations: Vec<usize>,
    u: FactorPanel,
    vs: Vec<FactorPanel>,
    link: Option<LinkPanels>,
    zero_copy: bool,
}

impl ServingModel {
    /// Open a store directory and build the model (zero-copy when the
    /// store is packed).
    pub fn load(dir: &Path) -> anyhow::Result<ServingModel> {
        ServingModel::from_store(&ModelStore::open(dir)?)
    }

    /// Build from an already-open store handle.
    pub fn from_store(store: &ModelStore) -> anyhow::Result<ServingModel> {
        if store.is_empty() {
            anyhow::bail!("model store {} holds no posterior samples", store.dir().display());
        }
        if store.is_packed() {
            // crash-window recovery: save_snapshot deletes packed/
            // before the manifest rename lands, so a manifest can claim
            // an artifact whose files are gone while every snapshot dir
            // is intact — serve from the dirs rather than brick the
            // store.  Packs that are *present* but invalid stay a loud
            // error (corruption must never silently fall back).
            if !crate::store::packed::u_pack_path(store.dir()).exists() {
                return ServingModel::from_snapshot_dirs(store);
            }
            ServingModel::from_packed(store)
        } else {
            ServingModel::from_snapshot_dirs(store)
        }
    }

    fn from_packed(store: &ModelStore) -> anyhow::Result<ServingModel> {
        let meta = store.meta().clone();
        let packed = store.open_packed()?;
        let k = meta.num_latent;
        let zero_copy = packed.zero_copy();
        let u_file = Arc::new(packed.u);
        let u = FactorPanel {
            rows: meta.nrows,
            cols: k,
            storage: PanelStorage::Packed { file: u_file, offset: 0 },
        };
        let mut vs = Vec::with_capacity(meta.total_mats());
        for (v, pf) in packed.views.into_iter().enumerate() {
            let file = Arc::new(pf);
            let mut offset = 0;
            for &d in &meta.view_dims[v] {
                vs.push(FactorPanel {
                    rows: d,
                    cols: k,
                    storage: PanelStorage::Packed { file: file.clone(), offset },
                });
                offset += d * k;
            }
        }
        let link = packed.link.map(|pf| {
            let file = Arc::new(pf);
            LinkPanels {
                beta: FactorPanel {
                    rows: meta.link_features,
                    cols: k,
                    storage: PanelStorage::Packed { file: file.clone(), offset: 0 },
                },
                mu: FactorPanel {
                    rows: 1,
                    cols: k,
                    storage: PanelStorage::Packed { file, offset: meta.link_features * k },
                },
            }
        });
        Ok(ServingModel {
            nsamples: store.len(),
            iterations: store.iterations(),
            meta,
            u,
            vs,
            link,
            zero_copy,
        })
    }

    fn from_snapshot_dirs(store: &ModelStore) -> anyhow::Result<ServingModel> {
        let meta = store.meta().clone();
        let k = meta.num_latent;
        let n = store.len();
        let mut u_buf = Vec::with_capacity(n * meta.nrows * k);
        let flat_dims: Vec<usize> = meta.view_dims.iter().flatten().copied().collect();
        let mut vs_bufs: Vec<Vec<f64>> =
            flat_dims.iter().map(|&d| Vec::with_capacity(n * d * k)).collect();
        let mut beta_buf = Vec::with_capacity(n * meta.link_features * k);
        let mut mu_buf = Vec::with_capacity(n * k);
        for i in 0..n {
            let snap = store.load_snapshot(i)?;
            // validate payload shapes against the manifest up front: all
            // serving paths bounds-check against the manifest only, and
            // a mismatch surfacing inside a pool worker would hang the
            // fork-join instead of propagating
            if snap.u.rows() != meta.nrows || snap.u.cols() != k {
                anyhow::bail!(
                    "sample {i}: U is {}x{}, manifest says {}x{k}",
                    snap.u.rows(),
                    snap.u.cols(),
                    meta.nrows,
                );
            }
            if snap.vs.len() != meta.total_mats() {
                anyhow::bail!(
                    "sample {i}: {} factor matrices, manifest says {}",
                    snap.vs.len(),
                    meta.total_mats()
                );
            }
            for (vi, (v, &nc)) in snap.vs.iter().zip(&flat_dims).enumerate() {
                if v.rows() != nc || v.cols() != k {
                    anyhow::bail!(
                        "sample {i}: V{vi} is {}x{}, manifest says {nc}x{k}",
                        v.rows(),
                        v.cols(),
                    );
                }
            }
            u_buf.extend_from_slice(snap.u.data());
            for (buf, v) in vs_bufs.iter_mut().zip(&snap.vs) {
                buf.extend_from_slice(v.data());
            }
            match (&snap.link, meta.link_features) {
                (Some(link), f) if f > 0 => {
                    if link.beta.rows() != f || link.beta.cols() != k || link.mu.len() != k {
                        anyhow::bail!("sample {i}: link shapes do not match the manifest");
                    }
                    beta_buf.extend_from_slice(link.beta.data());
                    mu_buf.extend_from_slice(&link.mu);
                }
                (None, 0) => {}
                _ => anyhow::bail!("sample {i}: link presence does not match the manifest"),
            }
        }
        let u = FactorPanel { rows: meta.nrows, cols: k, storage: PanelStorage::Owned(u_buf) };
        let vs = flat_dims
            .iter()
            .zip(vs_bufs)
            .map(|(&d, buf)| FactorPanel { rows: d, cols: k, storage: PanelStorage::Owned(buf) })
            .collect();
        let link = (meta.link_features > 0).then(|| LinkPanels {
            beta: FactorPanel {
                rows: meta.link_features,
                cols: k,
                storage: PanelStorage::Owned(beta_buf),
            },
            mu: FactorPanel { rows: 1, cols: k, storage: PanelStorage::Owned(mu_buf) },
        });
        Ok(ServingModel {
            nsamples: n,
            iterations: store.iterations(),
            meta,
            u,
            vs,
            link,
            zero_copy: false,
        })
    }

    pub fn meta(&self) -> &StoreMeta {
        &self.meta
    }

    /// Posterior samples held by the model.
    pub fn nsamples(&self) -> usize {
        self.nsamples
    }

    /// Training iterations the samples were drawn at, ascending.
    pub fn iterations(&self) -> &[usize] {
        &self.iterations
    }

    /// Whether every panel is served zero-copy out of mmap'd pack files.
    pub fn zero_copy(&self) -> bool {
        self.zero_copy
    }

    pub fn has_link(&self) -> bool {
        self.link.is_some()
    }

    /// Shared mode-0 factors of sample `s`.
    #[inline]
    pub fn u(&self, s: usize) -> MatRef<'_> {
        self.u.sample(s)
    }

    /// Flat factor matrix `fi` (in `Snapshot::vs` order) of sample `s`.
    #[inline]
    pub fn factor(&self, fi: usize, s: usize) -> MatRef<'_> {
        self.vs[fi].sample(s)
    }

    /// View `view`'s first further-mode factor of sample `s` (2-mode
    /// views: the classic V).
    #[inline]
    pub fn v2(&self, view: usize, s: usize) -> MatRef<'_> {
        self.vs[self.meta.vs_offset(view)].sample(s)
    }

    /// Macau link β (F × K) of sample `s`.
    pub fn link_beta(&self, s: usize) -> Option<MatRef<'_>> {
        self.link.as_ref().map(|l| l.beta.sample(s))
    }

    /// Macau link μ (length K) of sample `s`.
    pub fn link_mu(&self, s: usize) -> Option<&[f64]> {
        self.link.as_ref().map(|l| l.mu.sample(s).data())
    }
}
