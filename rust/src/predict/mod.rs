//! Predict sessions: serve a trained model from a posterior store
//! (SMURFF's `PredictSession`, Vander Aa et al. 2019 §3).
//!
//! A [`PredictSession`] opens a [`crate::store::ModelStore`] written by a
//! `TrainSession` with `save_freq > 0` and serves, without touching the
//! training stack again:
//!
//! * **pointwise** predictions averaged over the posterior samples, with
//!   the per-cell posterior predictive std-dev ([`Prediction`]);
//! * **dense-block** predictions — one GEMM per posterior sample, fanned
//!   out over the coordinator [`ThreadPool`] and reduced in sample order
//!   so results are identical for any thread count;
//! * **top-K recommendation** per row via a bounded binary heap over the
//!   candidate columns;
//! * **N-mode tensor serving** — pointwise mean±std at a coordinate
//!   tuple ([`PredictSession::predict_coords`]) and top-K over one free
//!   mode with the others fixed ([`PredictSession::top_k_mode`]), both
//!   via the per-sample Hadamard-dot (bit-identical to the matrix dot
//!   for 2-mode views);
//! * **out-of-matrix** prediction for rows never seen at training time,
//!   through the Macau prior's link model (u_new = μ + βᵀ f).
//!
//! Serving averages the *same* per-sample predictions the train session
//! aggregated, so a store saved every sampling iteration reproduces
//! `TrainResult::rmse` to ~1 ulp (tested below).

use crate::coordinator::ThreadPool;
use crate::linalg::{dot, gemm, Mat};
use crate::store::{ModelStore, Snapshot, StoreMeta};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::Range;
use std::path::Path;

/// A served prediction: posterior mean and predictive std-dev across the
/// stored samples (std is 0 with fewer than 2 samples, matching
/// [`crate::model::PredictionAggregator`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub mean: f64,
    pub std: f64,
}

/// Dense-block prediction result: per-cell means and std-devs for a
/// `rows × cols` rectangle of one view.
#[derive(Debug, Clone)]
pub struct BlockPrediction {
    pub rows: Range<usize>,
    pub cols: Range<usize>,
    pub mean: Mat,
    pub std: Mat,
}

/// A serving session over a loaded posterior store.
pub struct PredictSession {
    meta: StoreMeta,
    samples: Vec<Snapshot>,
    pool: ThreadPool,
}

impl PredictSession {
    /// Open a store directory and load every posterior sample into
    /// memory, with a pool sized from the machine.
    pub fn open(dir: &Path) -> anyhow::Result<PredictSession> {
        PredictSession::open_with_threads(dir, 0)
    }

    /// As [`open`](PredictSession::open) with an explicit worker count
    /// (0 = all available cores).
    pub fn open_with_threads(dir: &Path, threads: usize) -> anyhow::Result<PredictSession> {
        let store = ModelStore::open(dir)?;
        PredictSession::from_store(&store, threads)
    }

    /// Build a session from an already-open store handle.
    pub fn from_store(store: &ModelStore, threads: usize) -> anyhow::Result<PredictSession> {
        if store.is_empty() {
            anyhow::bail!("model store {} holds no posterior samples", store.dir().display());
        }
        let meta = store.meta().clone();
        let mut samples = Vec::with_capacity(store.len());
        for i in 0..store.len() {
            let snap = store.load_snapshot(i)?;
            // validate payload shapes against the manifest up front: all
            // serving paths bounds-check against the manifest only, and a
            // mismatch surfacing inside a pool worker would hang the call
            if snap.u.rows() != meta.nrows || snap.u.cols() != meta.num_latent {
                anyhow::bail!(
                    "sample {i}: U is {}x{}, manifest says {}x{}",
                    snap.u.rows(),
                    snap.u.cols(),
                    meta.nrows,
                    meta.num_latent
                );
            }
            if snap.vs.len() != meta.total_mats() {
                anyhow::bail!(
                    "sample {i}: {} factor matrices, manifest says {}",
                    snap.vs.len(),
                    meta.total_mats()
                );
            }
            for (vi, (v, &nc)) in snap.vs.iter().zip(meta.view_dims.iter().flatten()).enumerate() {
                if v.rows() != nc || v.cols() != meta.num_latent {
                    anyhow::bail!(
                        "sample {i}: V{vi} is {}x{}, manifest says {nc}x{}",
                        v.rows(),
                        v.cols(),
                        meta.num_latent
                    );
                }
            }
            if let Some(link) = &snap.link {
                if link.beta.rows() != meta.link_features
                    || link.beta.cols() != meta.num_latent
                    || link.mu.len() != meta.num_latent
                {
                    anyhow::bail!("sample {i}: link shapes do not match the manifest");
                }
            }
            samples.push(snap);
        }
        let pool = if threads == 0 { ThreadPool::default_size() } else { ThreadPool::new(threads) };
        Ok(PredictSession { meta, samples, pool })
    }

    pub fn nsamples(&self) -> usize {
        self.samples.len()
    }

    pub fn num_latent(&self) -> usize {
        self.meta.num_latent
    }

    pub fn nviews(&self) -> usize {
        self.meta.nviews()
    }

    pub fn nrows(&self) -> usize {
        self.meta.nrows
    }

    /// Column count of a 2-mode view (its first further mode).
    pub fn ncols(&self, view: usize) -> usize {
        self.meta.view_dims[view][0]
    }

    /// Number of modes of `view`, including the shared mode 0.
    pub fn nmodes(&self, view: usize) -> usize {
        1 + self.meta.view_dims[view].len()
    }

    /// Full per-mode dimensions of `view` (mode 0 first).
    pub fn mode_dims(&self, view: usize) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.nmodes(view));
        d.push(self.meta.nrows);
        d.extend_from_slice(&self.meta.view_dims[view]);
        d
    }

    /// The two-sided serving APIs (`predict_one`, `top_k`, blocks, link
    /// prediction) address a view by (row, col): they require a 2-mode
    /// view.  Tensor views serve through [`predict_coords`](Self::predict_coords)
    /// and [`top_k_mode`](Self::top_k_mode).
    fn check_two_mode(&self, view: usize) {
        assert!(view < self.nviews(), "view {view} out of range");
        assert_eq!(
            self.meta.view_dims[view].len(),
            1,
            "view {view} has {} modes; use predict_coords / top_k_mode",
            self.nmodes(view)
        );
    }

    /// View `view`'s first further-mode factor of sample `s` (2-mode
    /// views: the classic V).
    #[inline]
    fn v2(&self, s: usize, view: usize) -> &Mat {
        &self.samples[s].vs[self.meta.vs_offset(view)]
    }

    /// Per-mode factor refs of `view` in every sample (mode 0 = U).
    fn sample_factors(&self, view: usize) -> Vec<Vec<&Mat>> {
        let off = self.meta.vs_offset(view);
        let nm = self.meta.view_dims[view].len();
        self.samples
            .iter()
            .map(|snap| {
                let mut f: Vec<&Mat> = Vec::with_capacity(1 + nm);
                f.push(&snap.u);
                f.extend(snap.vs[off..off + nm].iter());
                f
            })
            .collect()
    }

    /// Whether the store carries a Macau link model (out-of-matrix
    /// prediction available).
    pub fn has_link(&self) -> bool {
        self.meta.link_features > 0
    }

    /// Serve from only the first `n` posterior samples — the latency /
    /// fidelity knob (fewer samples = faster, noisier).  No-op when `n`
    /// is at least the loaded count; keeps at least one sample.
    pub fn truncate_samples(&mut self, n: usize) {
        self.samples.truncate(n.max(1));
    }

    /// Posterior mean + std for one cell of one view.
    pub fn predict_one(&self, view: usize, row: usize, col: usize) -> Prediction {
        self.check_cell(view, row, col);
        let (sum, sumsq) = self.cell_moments(view, row, col);
        self.finish(sum, sumsq, view)
    }

    /// Pointwise predictions for an explicit cell list (the serving
    /// analogue of training's test-set aggregation), parallelized over
    /// cells.  `rows` and `cols` must have equal length.
    pub fn predict_cells(&self, view: usize, rows: &[u32], cols: &[u32]) -> Vec<Prediction> {
        assert_eq!(rows.len(), cols.len(), "rows/cols length mismatch");
        // validate on the caller thread: a panic inside a pool worker
        // would hang the fork-join instead of propagating
        for (&r, &c) in rows.iter().zip(cols) {
            self.check_cell(view, r as usize, c as usize);
        }
        self.pool.parallel_collect(rows.len(), 64, |i| {
            let (sum, sumsq) = self.cell_moments(view, rows[i] as usize, cols[i] as usize);
            self.finish(sum, sumsq, view)
        })
    }

    /// Dense-block prediction: one GEMM per posterior sample (U_blk ·
    /// V_blkᵀ), fanned out over the pool, reduced in sample order.
    pub fn predict_block(&self, view: usize, rows: Range<usize>, cols: Range<usize>) -> BlockPrediction {
        self.check_two_mode(view);
        assert!(rows.end <= self.meta.nrows, "row range beyond {}", self.meta.nrows);
        assert!(cols.end <= self.ncols(view), "col range beyond {}", self.ncols(view));
        let (nr, nc, k) = (rows.len(), cols.len(), self.meta.num_latent);

        // per-sample score blocks, computed in parallel
        let blocks: Vec<Mat> = self.pool.parallel_collect(self.samples.len(), 1, |s| {
            let snap = &self.samples[s];
            let mut ublk = Mat::zeros(nr, k);
            for (bi, i) in rows.clone().enumerate() {
                ublk.row_mut(bi).copy_from_slice(snap.u.row(i));
            }
            // V_blkᵀ laid out K × nc so the product is one plain GEMM
            let v = self.v2(s, view);
            let mut vt = Mat::zeros(k, nc);
            for (bj, j) in cols.clone().enumerate() {
                for (d, &x) in v.row(j).iter().enumerate() {
                    vt[(d, bj)] = x;
                }
            }
            gemm(&ublk, &vt)
        });

        // sequential sample-order reduction => thread-count independent
        let n = blocks.len() as f64;
        let mut sum = Mat::zeros(nr, nc);
        let mut sumsq = Mat::zeros(nr, nc);
        for b in &blocks {
            for ((s, ss), &p) in sum.data_mut().iter_mut().zip(sumsq.data_mut()).zip(b.data()) {
                *s += p;
                *ss += p * p;
            }
        }
        let offset = self.meta.offsets[view];
        let mut mean = Mat::zeros(nr, nc);
        let mut std = Mat::zeros(nr, nc);
        for i in 0..nr * nc {
            let s = sum.data()[i];
            mean.data_mut()[i] = s / n + offset;
            std.data_mut()[i] = variance(s, sumsq.data()[i], blocks.len()).sqrt();
        }
        BlockPrediction { rows, cols, mean, std }
    }

    /// Top-K recommendation: the K columns of `view` with the highest
    /// posterior-mean score for `row`, excluding `exclude` (e.g. the
    /// items the user already rated).  Returns (col, score) sorted by
    /// descending score; ties break toward the smaller column index so
    /// output is fully deterministic.
    pub fn top_k(&self, view: usize, row: usize, k: usize, exclude: &[u32]) -> Vec<(u32, f64)> {
        self.check_two_mode(view);
        assert!(row < self.meta.nrows, "row {row} out of range");
        let ncols = self.ncols(view);
        let excluded: std::collections::HashSet<u32> = exclude.iter().copied().collect();

        // scores for every candidate column, computed in parallel with
        // the exact accumulation predict_one uses (consistency contract)
        let scores: Vec<f64> = self
            .pool
            .parallel_collect(ncols, 128, |j| self.cell_moments(view, row, j).0);

        let n = self.samples.len() as f64;
        let offset = self.meta.offsets[view];
        // bounded min-heap of the best K seen so far
        let mut heap: BinaryHeap<std::cmp::Reverse<TopEntry>> = BinaryHeap::with_capacity(k + 1);
        for (j, &s) in scores.iter().enumerate() {
            let col = j as u32;
            if excluded.contains(&col) {
                continue;
            }
            let entry = TopEntry { score: s / n + offset, col };
            if heap.len() < k {
                heap.push(std::cmp::Reverse(entry));
            } else if let Some(min) = heap.peek() {
                if entry > min.0 {
                    heap.pop();
                    heap.push(std::cmp::Reverse(entry));
                }
            }
        }
        let mut out: Vec<(u32, f64)> =
            heap.into_iter().map(|r| (r.0.col, r.0.score)).collect();
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then_with(|| a.0.cmp(&b.0))
        });
        out
    }

    /// Out-of-matrix prediction: score `cols` of `view` for a row that
    /// was *not* part of training, from its side-info feature vector
    /// (dense, `link_features` long).  Per sample the row's latent is
    /// reconstructed as u = μ + βᵀ f through the stored Macau link
    /// model.
    pub fn predict_new_row(
        &self,
        features: &[f64],
        view: usize,
        cols: &[u32],
    ) -> anyhow::Result<Vec<Prediction>> {
        if self.meta.link_features == 0 {
            anyhow::bail!("store has no link model: train with a Macau row prior to serve unseen rows");
        }
        if features.len() != self.meta.link_features {
            anyhow::bail!(
                "feature vector has {} entries, link model expects {}",
                features.len(),
                self.meta.link_features
            );
        }
        self.check_two_mode(view);
        let ncols = self.ncols(view);
        for &c in cols {
            if c as usize >= ncols {
                anyhow::bail!("column {c} out of range ({ncols} columns)");
            }
        }
        let k = self.meta.num_latent;
        // per-sample reconstructed latent row u = μ + βᵀ f
        let mut us: Vec<Vec<f64>> = Vec::with_capacity(self.samples.len());
        for snap in &self.samples {
            let link = snap
                .link
                .as_ref()
                .ok_or_else(|| anyhow::anyhow!("snapshot {} lacks link data", snap.iteration))?;
            let mut u = crate::linalg::matvec_t(&link.beta, features);
            for (ud, m) in u.iter_mut().zip(&link.mu) {
                *ud += m;
            }
            debug_assert_eq!(u.len(), k);
            us.push(u);
        }
        let off = self.meta.vs_offset(view);
        let preds = self.pool.parallel_collect(cols.len(), 64, |ci| {
            let j = cols[ci] as usize;
            let (mut sum, mut sumsq) = (0.0, 0.0);
            for (snap, u) in self.samples.iter().zip(&us) {
                let p = dot(u, snap.vs[off].row(j));
                sum += p;
                sumsq += p * p;
            }
            self.finish(sum, sumsq, view)
        });
        Ok(preds)
    }

    /// Pointwise tensor serving: posterior mean ± std of one cell of an
    /// N-mode view addressed by its full coordinate tuple (mode 0
    /// first).  Per sample the cell is scored with the Hadamard-dot, so
    /// a 2-mode view gives exactly [`predict_one`](Self::predict_one)'s
    /// numbers.
    pub fn predict_coords(&self, view: usize, coords: &[usize]) -> Prediction {
        assert!(view < self.nviews(), "view {view} out of range");
        let dims = self.mode_dims(view);
        assert_eq!(coords.len(), dims.len(), "expected {} coordinates", dims.len());
        for (m, (&c, &d)) in coords.iter().zip(&dims).enumerate() {
            assert!(c < d, "coordinate {c} out of range for mode {m} (dim {d})");
        }
        let sf = self.sample_factors(view);
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for f in &sf {
            let p = crate::model::hadamard_dot(f, coords);
            sum += p;
            sumsq += p * p;
        }
        self.finish(sum, sumsq, view)
    }

    /// Top-K over one *free mode* of an N-mode view with every other
    /// coordinate fixed: the K indices of `free_mode` with the highest
    /// posterior-mean score (`coords[free_mode]` is ignored).  Scores
    /// are the exact per-sample Hadamard-dot sums `predict_coords`
    /// produces, so both APIs agree bitwise; ties break toward the
    /// smaller index.
    pub fn top_k_mode(
        &self,
        view: usize,
        coords: &[usize],
        free_mode: usize,
        k: usize,
        exclude: &[u32],
    ) -> Vec<(u32, f64)> {
        assert!(view < self.nviews(), "view {view} out of range");
        let dims = self.mode_dims(view);
        assert_eq!(coords.len(), dims.len(), "expected {} coordinates", dims.len());
        assert!(free_mode < dims.len(), "free mode {free_mode} out of range");
        for (m, (&c, &d)) in coords.iter().zip(&dims).enumerate() {
            assert!(m == free_mode || c < d, "coordinate {c} out of range for mode {m}");
        }
        let ncand = dims[free_mode];
        let excluded: std::collections::HashSet<u32> = exclude.iter().copied().collect();
        let sf = self.sample_factors(view);
        thread_local! {
            // per-thread candidate-coordinate scratch: no allocation per
            // candidate in the scoring hot loop
            static COORDS: std::cell::RefCell<Vec<usize>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        let scores: Vec<f64> = self.pool.parallel_collect(ncand, 64, |j| {
            COORDS.with(|c| {
                let mut c = c.borrow_mut();
                c.clear();
                c.extend_from_slice(coords);
                c[free_mode] = j;
                let mut sum = 0.0;
                for f in &sf {
                    sum += crate::model::hadamard_dot(f, &c);
                }
                sum
            })
        });
        let n = self.samples.len() as f64;
        let offset = self.meta.offsets[view];
        let mut heap: BinaryHeap<std::cmp::Reverse<TopEntry>> = BinaryHeap::with_capacity(k + 1);
        for (j, &s) in scores.iter().enumerate() {
            let cand = j as u32;
            if excluded.contains(&cand) {
                continue;
            }
            let entry = TopEntry { score: s / n + offset, col: cand };
            if heap.len() < k {
                heap.push(std::cmp::Reverse(entry));
            } else if let Some(min) = heap.peek() {
                if entry > min.0 {
                    heap.pop();
                    heap.push(std::cmp::Reverse(entry));
                }
            }
        }
        let mut out: Vec<(u32, f64)> = heap.into_iter().map(|r| (r.0.col, r.0.score)).collect();
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then_with(|| a.0.cmp(&b.0))
        });
        out
    }

    fn check_cell(&self, view: usize, row: usize, col: usize) {
        self.check_two_mode(view);
        assert!(row < self.meta.nrows, "row {row} out of range");
        assert!(col < self.ncols(view), "col {col} out of range");
    }

    /// (Σ_s p_s, Σ_s p_s²) over samples for one cell — the single
    /// accumulation routine every pointwise path shares, so top-K scores
    /// and `predict_one` means are bit-identical.
    #[inline]
    fn cell_moments(&self, view: usize, row: usize, col: usize) -> (f64, f64) {
        let off = self.meta.vs_offset(view);
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for snap in &self.samples {
            let p = dot(snap.u.row(row), snap.vs[off].row(col));
            sum += p;
            sumsq += p * p;
        }
        (sum, sumsq)
    }

    fn finish(&self, sum: f64, sumsq: f64, view: usize) -> Prediction {
        let n = self.samples.len();
        Prediction {
            mean: sum / n as f64 + self.meta.offsets[view],
            std: variance(sum, sumsq, n).sqrt(),
        }
    }
}

/// Sample variance from running moments (n-1 denominator, clamped at 0;
/// 0 below 2 samples) — the same estimator as `PredictionAggregator`.
fn variance(sum: f64, sumsq: f64, n: usize) -> f64 {
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    ((sumsq - sum * sum / nf) / (nf - 1.0)).max(0.0)
}

/// Heap entry ordered by score, ties toward the smaller column index.
#[derive(PartialEq)]
struct TopEntry {
    score: f64,
    col: u32,
}

impl Eq for TopEntry {}

impl PartialOrd for TopEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TopEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .partial_cmp(&other.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.col.cmp(&self.col))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{MatrixConfig, TestSet};
    use crate::noise::NoiseConfig;
    use crate::session::{SessionBuilder, SessionConfig, TrainSession};
    use crate::sparse::SparseMatrix;
    use std::path::PathBuf;

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("smurff_predict_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn saved_bmf(tag: &str) -> (crate::session::TrainResult, SparseMatrix, PathBuf) {
        let (train, test) = crate::data::movielens_like(80, 60, 2_500, 0.25, 51);
        let dir = scratch(tag);
        let cfg = SessionConfig {
            num_latent: 6,
            burnin: 6,
            nsamples: 12,
            seed: 51,
            threads: 2,
            save_freq: 1,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = TrainSession::bmf(train, Some(test.clone()), cfg);
        let r = s.run();
        (r, test, dir)
    }

    /// Acceptance (a): a store saved every sampling iteration serves the
    /// same posterior-mean RMSE the train session reported.
    #[test]
    fn served_average_matches_training_rmse() {
        let (r, test, dir) = saved_bmf("parity");
        assert_eq!(r.nsnapshots, 12);
        assert_eq!(r.store_path.as_deref(), Some(dir.as_path()));

        let ps = PredictSession::open(&dir).unwrap();
        assert_eq!(ps.nsamples(), 12);
        let t = TestSet::from_sparse(&test);
        let preds = ps.predict_cells(0, &t.rows, &t.cols);
        let means: Vec<f64> = preds.iter().map(|p| p.mean).collect();
        let rmse = crate::model::rmse(&means, &t.vals);
        assert!(
            (rmse - r.rmse).abs() < 1e-9,
            "served rmse {rmse} vs trained {}",
            r.rmse
        );
        // uncertainty is populated and sane
        assert!(preds.iter().all(|p| p.std.is_finite() && p.std >= 0.0));
        assert!(preds.iter().any(|p| p.std > 0.0));
    }

    /// Acceptance (b): top-K agrees with pointwise scoring — same values,
    /// and genuinely the K best.
    #[test]
    fn top_k_is_consistent_with_pointwise_scores() {
        let (_, _, dir) = saved_bmf("topk");
        let ps = PredictSession::open(&dir).unwrap();
        let user = 7;
        let k = 5;
        let top = ps.top_k(0, user, k, &[]);
        assert_eq!(top.len(), k);
        // scores descend and match predict_one exactly
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        for &(col, score) in &top {
            let p = ps.predict_one(0, user, col as usize);
            assert_eq!(score, p.mean, "top-k score must equal pointwise mean");
        }
        // nothing outside the list beats the list's minimum
        let floor = top.last().unwrap().1;
        let in_list: std::collections::HashSet<u32> = top.iter().map(|t| t.0).collect();
        for j in 0..ps.ncols(0) {
            if !in_list.contains(&(j as u32)) {
                assert!(ps.predict_one(0, user, j).mean <= floor);
            }
        }
        // exclusion removes items from the candidate set
        let excl: Vec<u32> = top.iter().map(|t| t.0).collect();
        let top2 = ps.top_k(0, user, k, &excl);
        assert!(top2.iter().all(|t| !in_list.contains(&t.0)));
        assert!(top2.first().unwrap().1 <= floor);
    }

    /// The tensor serving APIs collapse to the two-sided ones on 2-mode
    /// views — bit-for-bit, because the Hadamard-dot replays `dot`.
    #[test]
    fn tensor_apis_agree_with_two_sided_on_matrix_stores() {
        let (_, _, dir) = saved_bmf("tensorapi");
        let ps = PredictSession::open(&dir).unwrap();
        assert_eq!(ps.nmodes(0), 2);
        assert_eq!(ps.mode_dims(0), vec![ps.nrows(), ps.ncols(0)]);
        let p = ps.predict_one(0, 4, 9);
        let pc = ps.predict_coords(0, &[4, 9]);
        assert_eq!(p, pc);
        let t1 = ps.top_k(0, 4, 5, &[]);
        let t2 = ps.top_k_mode(0, &[4, 0], 1, 5, &[]);
        assert_eq!(t1, t2);
        // exclusion behaves identically too
        let excl: Vec<u32> = t1.iter().map(|t| t.0).collect();
        assert_eq!(ps.top_k(0, 4, 3, &excl), ps.top_k_mode(0, &[4, 0], 1, 3, &excl));
    }

    #[test]
    fn block_prediction_matches_pointwise() {
        let (_, _, dir) = saved_bmf("block");
        let ps = PredictSession::open_with_threads(&dir, 3).unwrap();
        let blk = ps.predict_block(0, 5..15, 3..9);
        assert_eq!((blk.mean.rows(), blk.mean.cols()), (10, 6));
        for bi in 0..10 {
            for bj in 0..6 {
                let p = ps.predict_one(0, 5 + bi, 3 + bj);
                assert!(
                    (blk.mean[(bi, bj)] - p.mean).abs() < 1e-9,
                    "mean mismatch at ({bi},{bj})"
                );
                assert!((blk.std[(bi, bj)] - p.std).abs() < 1e-9);
            }
        }
        // thread count must not change block results
        let ps1 = PredictSession::open_with_threads(&dir, 1).unwrap();
        let blk1 = ps1.predict_block(0, 5..15, 3..9);
        assert_eq!(blk.mean.max_abs_diff(&blk1.mean), 0.0);
        assert_eq!(blk.std.max_abs_diff(&blk1.std), 0.0);
    }

    /// Acceptance (c): out-of-matrix Macau prediction for rows held out
    /// of training beats the global-mean baseline.
    #[test]
    fn out_of_matrix_beats_global_mean() {
        let d = crate::data::chembl_synth(&crate::data::ChemblSpec {
            compounds: 100,
            proteins: 30,
            nnz: 3_000,
            fp_bits: 64,
            fp_density: 8,
            seed: 52,
            ..Default::default()
        });
        // hold rows 0..5 out of training entirely
        const HELD: u32 = 5;
        let all: Vec<(u32, u32, f64)> = d.activity.triplets().collect();
        let train: Vec<_> = all.iter().copied().filter(|t| t.0 >= HELD).collect();
        let held: Vec<_> = all.iter().copied().filter(|t| t.0 < HELD).collect();
        assert!(held.len() >= 5, "need held-out cells, got {}", held.len());
        let train_m =
            SparseMatrix::from_triplets(d.activity.nrows(), d.activity.ncols(), train);

        let dir = scratch("oom");
        let cfg = SessionConfig {
            num_latent: 4,
            burnin: 15,
            nsamples: 20,
            seed: 52,
            threads: 2,
            save_freq: 2,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = SessionBuilder::new(cfg)
            .row_macau(d.fingerprints_sparse.clone())
            .add_view(
                MatrixConfig::SparseUnknown(train_m.clone()),
                NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 },
                None,
            )
            .build();
        let r = s.run();
        assert_eq!(r.nsnapshots, 10);

        let ps = PredictSession::open(&dir).unwrap();
        assert!(ps.has_link());
        let mut feats = vec![0.0; 64];
        let mut preds = Vec::new();
        let mut truth = Vec::new();
        for row in 0..HELD {
            let cols: Vec<u32> =
                held.iter().filter(|t| t.0 == row).map(|t| t.1).collect();
            if cols.is_empty() {
                continue;
            }
            d.fingerprints_sparse.row_dense(row as usize, &mut feats);
            for p in ps.predict_new_row(&feats, 0, &cols).unwrap() {
                preds.push(p.mean);
            }
            truth.extend(held.iter().filter(|t| t.0 == row).map(|t| t.2));
        }
        let rmse_oom = crate::model::rmse(&preds, &truth);
        let global_mean = train_m.mean_value();
        let rmse_mean = crate::model::rmse(&vec![global_mean; truth.len()], &truth);
        assert!(
            rmse_oom < rmse_mean,
            "out-of-matrix rmse {rmse_oom} must beat global-mean {rmse_mean}"
        );
    }

    #[test]
    fn new_row_requires_link_and_matching_features() {
        let (_, _, dir) = saved_bmf("nolink");
        let ps = PredictSession::open(&dir).unwrap();
        assert!(!ps.has_link());
        assert!(ps.predict_new_row(&[0.0; 8], 0, &[1]).is_err());
    }

    #[test]
    fn open_rejects_manifest_payload_mismatch() {
        let (_, _, dir) = saved_bmf("corrupt");
        // clobber one sample's U with a wrong-shape payload: opening must
        // error instead of serving out-of-bounds reads later
        let store = crate::store::ModelStore::open(&dir).unwrap();
        let sample = dir.join(format!("sample_{:05}", store.iterations()[0]));
        crate::sparse::io::write_dbm(&Mat::zeros(3, 3), &sample.join("u.dbm")).unwrap();
        let err = PredictSession::open(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest says"), "{err}");
    }

    #[test]
    fn single_sample_store_has_zero_std() {
        let (train, _) = crate::data::movielens_like(30, 20, 400, 0.0, 53);
        let dir = scratch("one");
        let cfg = SessionConfig {
            num_latent: 3,
            burnin: 2,
            nsamples: 1,
            threads: 1,
            save_freq: 1,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = TrainSession::bmf(train, None, cfg);
        let r = s.run();
        assert_eq!(r.nsnapshots, 1);
        let ps = PredictSession::open(&dir).unwrap();
        let p = ps.predict_one(0, 0, 0);
        assert_eq!(p.std, 0.0);
        assert!(p.mean.is_finite());
    }
}
