//! Predict sessions: serve a trained model from a posterior store
//! (SMURFF's `PredictSession`, Vander Aa et al. 2019 §3).
//!
//! A [`PredictSession`] wraps an immutable [`Arc<ServingModel>`] — the
//! contiguous sample-major factor panels built from a
//! [`crate::store::ModelStore`] (zero-copy mmap panels on a packed v3
//! store) — and serves, without touching the training stack again:
//!
//! * **pointwise** predictions averaged over the posterior samples, with
//!   the per-cell posterior predictive std-dev ([`Prediction`]) —
//!   batched: queries are grouped by row so each (sample, row) latent
//!   loads once, with a posterior-mean-only fast path
//!   ([`PredictSession::predict_cells_mean`]) next to the full
//!   mean±std path;
//! * **top-K recommendation** per row: per sample one batched-dot pass
//!   over the contiguous candidate panel ([`crate::linalg::dots_into`])
//!   instead of a scalar loop per (sample, candidate), then a bounded
//!   binary heap with deterministic index tie-breaking;
//! * **dense-block** predictions — one GEMM per posterior sample
//!   straight off the borrowed row panel, fanned out over the
//!   coordinator [`ThreadPool`] and reduced in sample order so results
//!   are identical for any thread count;
//! * **N-mode tensor serving** — pointwise mean±std at a coordinate
//!   tuple ([`PredictSession::predict_coords`]) and top-K over one free
//!   mode with the others fixed ([`PredictSession::top_k_mode`]);
//! * **out-of-matrix** prediction for rows never seen at training time,
//!   through the Macau prior's link model (u_new = μ + βᵀ f).
//!
//! Every batched path accumulates per cell in posterior-sample order
//! with [`crate::linalg::dot`]'s exact arithmetic, so results are
//! **bit-identical** to the per-sample scalar path of the seed
//! implementation (asserted in tests) — the batching only changes the
//! memory walk, not the numbers.  Serving averages the *same*
//! per-sample predictions the train session aggregated, so a store
//! saved every sampling iteration reproduces `TrainResult::rmse` to
//! ~1 ulp (tested below).

mod serving_model;

pub use serving_model::{FactorPanel, ServingModel};

use crate::coordinator::ThreadPool;
use crate::linalg::{dot, dots_into, gemm_ref_into, Backend, Mat, MatRef};
use crate::model::hadamard_dot;
use crate::store::{ModelStore, StoreMeta};
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::ops::Range;
use std::path::Path;
use std::sync::Arc;

/// A served prediction: posterior mean and predictive std-dev across the
/// stored samples (std is 0 with fewer than 2 samples, matching
/// [`crate::model::PredictionAggregator`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    pub mean: f64,
    pub std: f64,
}

/// Dense-block prediction result: per-cell means and std-devs for a
/// `rows × cols` rectangle of one view.
#[derive(Debug, Clone)]
pub struct BlockPrediction {
    pub rows: Range<usize>,
    pub cols: Range<usize>,
    pub mean: Mat,
    pub std: Mat,
}

/// Candidate panel rows scored per parallel chunk by the batched top-K
/// path (columns are chunked, samples stream inside each chunk).
const TOPK_CHUNK: usize = 256;

/// Cells per parallel work item of the batched pointwise engine: row
/// groups larger than this split into chunks so a single-row batch (one
/// user, many candidates) still fans out across the pool.  Per-cell
/// accumulation order is unchanged by the split.
const GROUP_CELLS: usize = 256;

/// A serving session over an immutable, shareable posterior model.
pub struct PredictSession {
    model: Arc<ServingModel>,
    /// samples actually served (the latency/fidelity knob); the first
    /// `nserve` of the model's samples, never 0
    nserve: usize,
    pool: Arc<ThreadPool>,
}

impl PredictSession {
    /// Open a store directory and build the serving model (zero-copy on
    /// a packed store), with a pool sized from the machine.
    pub fn open(dir: &Path) -> anyhow::Result<PredictSession> {
        PredictSession::open_with_threads(dir, 0)
    }

    /// As [`open`](PredictSession::open) with an explicit worker count
    /// (0 = all available cores).
    pub fn open_with_threads(dir: &Path, threads: usize) -> anyhow::Result<PredictSession> {
        let store = ModelStore::open(dir)?;
        PredictSession::from_store(&store, threads)
    }

    /// Build a session from an already-open store handle.
    pub fn from_store(store: &ModelStore, threads: usize) -> anyhow::Result<PredictSession> {
        PredictSession::from_model(Arc::new(ServingModel::from_store(store)?), threads)
    }

    /// Build a session over an already-built model (the serve engine's
    /// entry point: models are shared and hot-swapped as `Arc`s).
    pub fn from_model(model: Arc<ServingModel>, threads: usize) -> anyhow::Result<PredictSession> {
        let pool = if threads == 0 { ThreadPool::default_size() } else { ThreadPool::new(threads) };
        Ok(PredictSession { nserve: model.nsamples(), model, pool: Arc::new(pool) })
    }

    /// A new session over `model` sharing this session's thread pool —
    /// the hot-reload primitive: the serve engine swaps the returned
    /// session in atomically while in-flight requests finish on the old
    /// one.  Serves every sample of the new model.
    pub fn with_model(&self, model: Arc<ServingModel>) -> PredictSession {
        PredictSession { nserve: model.nsamples(), model, pool: self.pool.clone() }
    }

    /// The shared, immutable model this session serves.
    pub fn model(&self) -> Arc<ServingModel> {
        self.model.clone()
    }

    /// Whether factors are served zero-copy out of a packed artifact.
    pub fn zero_copy(&self) -> bool {
        self.model.zero_copy()
    }

    fn meta(&self) -> &StoreMeta {
        self.model.meta()
    }

    pub fn nsamples(&self) -> usize {
        self.nserve
    }

    pub fn num_latent(&self) -> usize {
        self.meta().num_latent
    }

    pub fn nviews(&self) -> usize {
        self.meta().nviews()
    }

    pub fn nrows(&self) -> usize {
        self.meta().nrows
    }

    /// Column count of a 2-mode view (its first further mode).
    pub fn ncols(&self, view: usize) -> usize {
        self.meta().view_dims[view][0]
    }

    /// Number of modes of `view`, including the shared mode 0.
    pub fn nmodes(&self, view: usize) -> usize {
        1 + self.meta().view_dims[view].len()
    }

    /// Full per-mode dimensions of `view` (mode 0 first).
    pub fn mode_dims(&self, view: usize) -> Vec<usize> {
        let mut d = Vec::with_capacity(self.nmodes(view));
        d.push(self.meta().nrows);
        d.extend_from_slice(&self.meta().view_dims[view]);
        d
    }

    /// The two-sided serving APIs (`predict_one`, `top_k`, blocks, link
    /// prediction) address a view by (row, col): they require a 2-mode
    /// view.  Tensor views serve through [`predict_coords`](Self::predict_coords)
    /// and [`top_k_mode`](Self::top_k_mode).
    fn check_two_mode(&self, view: usize) {
        assert!(view < self.nviews(), "view {view} out of range");
        assert_eq!(
            self.meta().view_dims[view].len(),
            1,
            "view {view} has {} modes; use predict_coords / top_k_mode",
            self.nmodes(view)
        );
    }

    /// Per-mode factor views of `view` for every served sample (mode 0
    /// = U) — the tensor APIs' access pattern.
    fn sample_factors(&self, view: usize) -> Vec<Vec<MatRef<'_>>> {
        let off = self.meta().vs_offset(view);
        let nm = self.meta().view_dims[view].len();
        (0..self.nserve)
            .map(|s| {
                let mut f: Vec<MatRef<'_>> = Vec::with_capacity(1 + nm);
                f.push(self.model.u(s));
                f.extend((0..nm).map(|m| self.model.factor(off + m, s)));
                f
            })
            .collect()
    }

    /// Whether the store carries a Macau link model (out-of-matrix
    /// prediction available).
    pub fn has_link(&self) -> bool {
        self.meta().link_features > 0
    }

    /// Serve from only the first `n` posterior samples — the latency /
    /// fidelity knob (fewer samples = faster, noisier).  No-op when `n`
    /// is at least the loaded count; keeps at least one sample.
    pub fn truncate_samples(&mut self, n: usize) {
        self.nserve = n.clamp(1, self.model.nsamples());
    }

    /// Posterior mean + std for one cell of one view.
    pub fn predict_one(&self, view: usize, row: usize, col: usize) -> Prediction {
        self.check_cell(view, row, col);
        let (sum, sumsq) = self.cell_moments(view, row, col);
        self.finish(sum, sumsq, view)
    }

    /// Pointwise predictions for an explicit cell list (the serving
    /// analogue of training's test-set aggregation).  Queries are
    /// grouped by row and parallelized over the groups: per (group,
    /// sample) the row's latent vector loads once and the group's
    /// candidate columns stream through the contiguous factor panel —
    /// bit-identical to scoring each cell alone.  `rows` and `cols`
    /// must have equal length.
    pub fn predict_cells(&self, view: usize, rows: &[u32], cols: &[u32]) -> Vec<Prediction> {
        let (sums, sqs) = self.batched_moments(view, rows, cols, true);
        sums.iter()
            .zip(&sqs)
            .map(|(&s, &ss)| self.finish(s, ss, view))
            .collect()
    }

    /// The posterior-mean fast path of [`predict_cells`](Self::predict_cells):
    /// same batched engine and bit-identical means, but skips the
    /// second-moment accumulation entirely — for traffic that does not
    /// ask for uncertainty.
    pub fn predict_cells_mean(&self, view: usize, rows: &[u32], cols: &[u32]) -> Vec<f64> {
        let n = self.nserve as f64;
        let offset = self.meta().offsets[view];
        let (sums, _) = self.batched_moments(view, rows, cols, false);
        sums.iter().map(|s| s / n + offset).collect()
    }

    /// Shared batched accumulator: per query cell (Σ_s p_s, and with
    /// `want_sq` Σ_s p_s²) in posterior-sample order — the exact
    /// arithmetic of [`cell_moments`](Self::cell_moments), restructured
    /// as row-grouped panel walks.
    fn batched_moments(
        &self,
        view: usize,
        rows: &[u32],
        cols: &[u32],
        want_sq: bool,
    ) -> (Vec<f64>, Vec<f64>) {
        assert_eq!(rows.len(), cols.len(), "rows/cols length mismatch");
        let nq = rows.len();
        // validate on the caller thread: a panic inside a pool worker
        // would hang the fork-join instead of propagating
        for (&r, &c) in rows.iter().zip(cols) {
            self.check_cell(view, r as usize, c as usize);
        }
        if nq == 0 {
            return (Vec::new(), Vec::new());
        }
        // group query indices by row (then column, for a monotone walk
        // over the factor panel); the sort is total, so grouping is
        // deterministic
        let mut order: Vec<u32> = (0..nq as u32).collect();
        order.sort_by_key(|&i| (rows[i as usize], cols[i as usize], i));
        let mut groups: Vec<Range<usize>> = Vec::new();
        let mut g0 = 0;
        for i in 1..=nq {
            if i == nq || rows[order[i] as usize] != rows[order[g0] as usize] {
                // split oversized row groups so one hot row cannot
                // serialize the whole batch onto a single lane
                let mut c = g0;
                while c < i {
                    groups.push(c..(c + GROUP_CELLS).min(i));
                    c += GROUP_CELLS;
                }
                g0 = i;
            }
        }
        let off = self.meta().vs_offset(view);
        let parts: Vec<(Vec<f64>, Vec<f64>)> = self.pool.parallel_collect(groups.len(), 1, |g| {
            let idxs = &order[groups[g].clone()];
            let row = rows[idxs[0] as usize] as usize;
            let mut sums = vec![0.0; idxs.len()];
            let mut sqs = vec![0.0; if want_sq { idxs.len() } else { 0 }];
            for s in 0..self.nserve {
                let u_row = self.model.u(s).row(row);
                let v = self.model.factor(off, s);
                for (qi, &q) in idxs.iter().enumerate() {
                    let p = dot(u_row, v.row(cols[q as usize] as usize));
                    sums[qi] += p;
                    if want_sq {
                        sqs[qi] += p * p;
                    }
                }
            }
            (sums, sqs)
        });
        // scatter back to the input query order
        let mut sums = vec![0.0; nq];
        let mut sqs = vec![0.0; if want_sq { nq } else { 0 }];
        for (range, (gsums, gsqs)) in groups.iter().zip(parts) {
            for (qi, &q) in order[range.clone()].iter().enumerate() {
                sums[q as usize] = gsums[qi];
                if want_sq {
                    sqs[q as usize] = gsqs[qi];
                }
            }
        }
        (sums, sqs)
    }

    /// Dense-block prediction: one GEMM per posterior sample, straight
    /// off the borrowed sample-major row panel (no U gather, no clone),
    /// fanned out over the pool, reduced in sample order.
    pub fn predict_block(&self, view: usize, rows: Range<usize>, cols: Range<usize>) -> BlockPrediction {
        self.check_two_mode(view);
        assert!(rows.end <= self.meta().nrows, "row range beyond {}", self.meta().nrows);
        assert!(cols.end <= self.ncols(view), "col range beyond {}", self.ncols(view));
        let (nr, nc, k) = (rows.len(), cols.len(), self.meta().num_latent);

        // per-sample score blocks, computed in parallel
        let blocks: Vec<Mat> = self.pool.parallel_collect(self.nserve, 1, |s| {
            // the row range is contiguous in the panel: borrow it as-is
            let u = self.model.u(s);
            let ublk = MatRef::new(nr, k, &u.data()[rows.start * k..rows.end * k]);
            // V_blkᵀ laid out K × nc so the product is one plain GEMM
            let v = self.model.v2(view, s);
            let mut vt = Mat::zeros(k, nc);
            for (bj, j) in cols.clone().enumerate() {
                for (d, &x) in v.row(j).iter().enumerate() {
                    vt[(d, bj)] = x;
                }
            }
            let mut c = Mat::zeros(nr, nc);
            gemm_ref_into(ublk, vt.view(), &mut c, Backend::global());
            c
        });

        // sequential sample-order reduction => thread-count independent
        let n = blocks.len() as f64;
        let mut sum = Mat::zeros(nr, nc);
        let mut sumsq = Mat::zeros(nr, nc);
        for b in &blocks {
            for ((s, ss), &p) in sum.data_mut().iter_mut().zip(sumsq.data_mut()).zip(b.data()) {
                *s += p;
                *ss += p * p;
            }
        }
        let offset = self.meta().offsets[view];
        let mut mean = Mat::zeros(nr, nc);
        let mut std = Mat::zeros(nr, nc);
        for i in 0..nr * nc {
            let s = sum.data()[i];
            mean.data_mut()[i] = s / n + offset;
            std.data_mut()[i] = variance(s, sumsq.data()[i], blocks.len()).sqrt();
        }
        BlockPrediction { rows, cols, mean, std }
    }

    /// Raw posterior score sums (Σ_s p_s) for every candidate column of
    /// `row` — the batched engine under [`top_k`](Self::top_k):
    /// candidates are chunked across the pool and, inside each chunk,
    /// the samples stream one [`dots_into`] pass over the contiguous
    /// candidate panel.  Per candidate the accumulation is in sample
    /// order with `dot`'s arithmetic — bit-identical to
    /// [`cell_moments`](Self::cell_moments)'s sum.
    fn row_scores(&self, view: usize, row: usize) -> Vec<f64> {
        let ncols = self.ncols(view);
        let k = self.meta().num_latent;
        let off = self.meta().vs_offset(view);
        let nchunks = ncols.div_ceil(TOPK_CHUNK);
        let parts: Vec<Vec<f64>> = self.pool.parallel_collect(nchunks, 1, |c| {
            let j0 = c * TOPK_CHUNK;
            let j1 = (j0 + TOPK_CHUNK).min(ncols);
            let mut out = vec![0.0; j1 - j0];
            for s in 0..self.nserve {
                let u_row = self.model.u(s).row(row);
                let v = self.model.factor(off, s);
                let panel = MatRef::new(j1 - j0, k, &v.data()[j0 * k..j1 * k]);
                dots_into(u_row, panel, &mut out);
            }
            out
        });
        let mut scores = Vec::with_capacity(ncols);
        for p in parts {
            scores.extend(p);
        }
        scores
    }

    /// Top-K recommendation: the K columns of `view` with the highest
    /// posterior-mean score for `row`, excluding `exclude` (e.g. the
    /// items the user already rated).  Returns (col, score) sorted by
    /// descending score; equal scores order deterministically by
    /// ascending column index — both within the returned list and at
    /// the K boundary (the kept set prefers smaller indices), so output
    /// never depends on heap iteration order.
    pub fn top_k(&self, view: usize, row: usize, k: usize, exclude: &[u32]) -> Vec<(u32, f64)> {
        self.check_two_mode(view);
        assert!(row < self.meta().nrows, "row {row} out of range");
        let scores = self.row_scores(view, row);
        self.select_top_k(&scores, k, exclude, self.meta().offsets[view])
    }

    /// Bounded-heap selection shared by [`top_k`](Self::top_k) and
    /// [`top_k_mode`](Self::top_k_mode): scores are raw per-sample sums;
    /// ties break toward the smaller index everywhere.
    fn select_top_k(&self, scores: &[f64], k: usize, exclude: &[u32], offset: f64) -> Vec<(u32, f64)> {
        let n = self.nserve as f64;
        let excluded: std::collections::HashSet<u32> = exclude.iter().copied().collect();
        // bounded min-heap of the best K seen so far; TopEntry's order
        // makes the heap minimum the (lowest-score, largest-index)
        // entry, so on a tie the larger index is evicted first.  The
        // heap can never hold more than the candidate count, so the
        // preallocation is capped there — a huge k must not translate
        // into a huge allocation
        let mut heap: BinaryHeap<std::cmp::Reverse<TopEntry>> =
            BinaryHeap::with_capacity(k.min(scores.len()) + 1);
        for (j, &s) in scores.iter().enumerate() {
            let col = j as u32;
            if excluded.contains(&col) {
                continue;
            }
            let entry = TopEntry { score: s / n + offset, col };
            if heap.len() < k {
                heap.push(std::cmp::Reverse(entry));
            } else if let Some(min) = heap.peek() {
                if entry > min.0 {
                    heap.pop();
                    heap.push(std::cmp::Reverse(entry));
                }
            }
        }
        let mut out: Vec<(u32, f64)> = heap.into_iter().map(|r| (r.0.col, r.0.score)).collect();
        out.sort_by(|a, b| {
            b.1.partial_cmp(&a.1).unwrap_or(Ordering::Equal).then_with(|| a.0.cmp(&b.0))
        });
        out
    }

    /// Out-of-matrix prediction: score `cols` of `view` for a row that
    /// was *not* part of training, from its side-info feature vector
    /// (dense, `link_features` long).  Per sample the row's latent is
    /// reconstructed as u = μ + βᵀ f through the stored Macau link
    /// model.
    pub fn predict_new_row(
        &self,
        features: &[f64],
        view: usize,
        cols: &[u32],
    ) -> anyhow::Result<Vec<Prediction>> {
        if self.meta().link_features == 0 {
            anyhow::bail!("store has no link model: train with a Macau row prior to serve unseen rows");
        }
        if features.len() != self.meta().link_features {
            anyhow::bail!(
                "feature vector has {} entries, link model expects {}",
                features.len(),
                self.meta().link_features
            );
        }
        self.check_two_mode(view);
        let ncols = self.ncols(view);
        for &c in cols {
            if c as usize >= ncols {
                anyhow::bail!("column {c} out of range ({ncols} columns)");
            }
        }
        let k = self.meta().num_latent;
        // per-sample reconstructed latent row u = μ + βᵀ f
        let mut us: Vec<Vec<f64>> = Vec::with_capacity(self.nserve);
        for s in 0..self.nserve {
            let beta = self.model.link_beta(s).expect("link presence checked");
            let mut u = crate::linalg::matvec_t_ref(beta, features);
            for (ud, m) in u.iter_mut().zip(self.model.link_mu(s).expect("link presence checked")) {
                *ud += m;
            }
            debug_assert_eq!(u.len(), k);
            us.push(u);
        }
        let off = self.meta().vs_offset(view);
        let preds = self.pool.parallel_collect(cols.len(), 64, |ci| {
            let j = cols[ci] as usize;
            let (mut sum, mut sumsq) = (0.0, 0.0);
            for (s, u) in us.iter().enumerate() {
                let p = dot(u, self.model.factor(off, s).row(j));
                sum += p;
                sumsq += p * p;
            }
            self.finish(sum, sumsq, view)
        });
        Ok(preds)
    }

    /// Pointwise tensor serving: posterior mean ± std of one cell of an
    /// N-mode view addressed by its full coordinate tuple (mode 0
    /// first).  Per sample the cell is scored with the Hadamard-dot, so
    /// a 2-mode view gives exactly [`predict_one`](Self::predict_one)'s
    /// numbers.
    pub fn predict_coords(&self, view: usize, coords: &[usize]) -> Prediction {
        assert!(view < self.nviews(), "view {view} out of range");
        let dims = self.mode_dims(view);
        assert_eq!(coords.len(), dims.len(), "expected {} coordinates", dims.len());
        for (m, (&c, &d)) in coords.iter().zip(&dims).enumerate() {
            assert!(c < d, "coordinate {c} out of range for mode {m} (dim {d})");
        }
        let sf = self.sample_factors(view);
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for f in &sf {
            let p = hadamard_dot(f, coords);
            sum += p;
            sumsq += p * p;
        }
        self.finish(sum, sumsq, view)
    }

    /// Top-K over one *free mode* of an N-mode view with every other
    /// coordinate fixed: the K indices of `free_mode` with the highest
    /// posterior-mean score (`coords[free_mode]` is ignored).  Scores
    /// are the exact per-sample Hadamard-dot sums `predict_coords`
    /// produces, so both APIs agree bitwise; equal scores order
    /// deterministically by ascending index, as in [`top_k`](Self::top_k).
    pub fn top_k_mode(
        &self,
        view: usize,
        coords: &[usize],
        free_mode: usize,
        k: usize,
        exclude: &[u32],
    ) -> Vec<(u32, f64)> {
        assert!(view < self.nviews(), "view {view} out of range");
        let dims = self.mode_dims(view);
        assert_eq!(coords.len(), dims.len(), "expected {} coordinates", dims.len());
        assert!(free_mode < dims.len(), "free mode {free_mode} out of range");
        for (m, (&c, &d)) in coords.iter().zip(&dims).enumerate() {
            assert!(m == free_mode || c < d, "coordinate {c} out of range for mode {m}");
        }
        let ncand = dims[free_mode];
        let sf = self.sample_factors(view);
        thread_local! {
            // per-thread candidate-coordinate scratch: no allocation per
            // candidate in the scoring hot loop
            static COORDS: std::cell::RefCell<Vec<usize>> =
                const { std::cell::RefCell::new(Vec::new()) };
        }
        let scores: Vec<f64> = self.pool.parallel_collect(ncand, 64, |j| {
            COORDS.with(|c| {
                let mut c = c.borrow_mut();
                c.clear();
                c.extend_from_slice(coords);
                c[free_mode] = j;
                let mut sum = 0.0;
                for f in &sf {
                    sum += hadamard_dot(f, &c);
                }
                sum
            })
        });
        self.select_top_k(&scores, k, exclude, self.meta().offsets[view])
    }

    fn check_cell(&self, view: usize, row: usize, col: usize) {
        self.check_two_mode(view);
        assert!(row < self.meta().nrows, "row {row} out of range");
        assert!(col < self.ncols(view), "col {col} out of range");
    }

    /// (Σ_s p_s, Σ_s p_s²) over samples for one cell — the reference
    /// accumulation every batched path reproduces bit-exactly, so top-K
    /// scores and `predict_one` means are interchangeable.
    #[inline]
    fn cell_moments(&self, view: usize, row: usize, col: usize) -> (f64, f64) {
        let off = self.meta().vs_offset(view);
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for s in 0..self.nserve {
            let p = dot(self.model.u(s).row(row), self.model.factor(off, s).row(col));
            sum += p;
            sumsq += p * p;
        }
        (sum, sumsq)
    }

    fn finish(&self, sum: f64, sumsq: f64, view: usize) -> Prediction {
        let n = self.nserve;
        Prediction {
            mean: sum / n as f64 + self.meta().offsets[view],
            std: variance(sum, sumsq, n).sqrt(),
        }
    }
}

/// Sample variance from running moments (n-1 denominator, clamped at 0;
/// 0 below 2 samples) — the same estimator as `PredictionAggregator`.
fn variance(sum: f64, sumsq: f64, n: usize) -> f64 {
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    ((sumsq - sum * sum / nf) / (nf - 1.0)).max(0.0)
}

/// Heap entry ordered by score, ties toward the smaller column index.
#[derive(PartialEq)]
struct TopEntry {
    score: f64,
    col: u32,
}

impl Eq for TopEntry {}

impl PartialOrd for TopEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TopEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.score
            .partial_cmp(&other.score)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.col.cmp(&self.col))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{MatrixConfig, TestSet};
    use crate::noise::NoiseConfig;
    use crate::session::{SessionBuilder, SessionConfig, TrainSession};
    use crate::sparse::SparseMatrix;
    use crate::store::Snapshot;
    use std::path::PathBuf;

    fn scratch(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("smurff_predict_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn saved_bmf(tag: &str) -> (crate::session::TrainResult, SparseMatrix, PathBuf) {
        let (train, test) = crate::data::movielens_like(80, 60, 2_500, 0.25, 51);
        let dir = scratch(tag);
        let cfg = SessionConfig {
            num_latent: 6,
            burnin: 6,
            nsamples: 12,
            seed: 51,
            threads: 2,
            save_freq: 1,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = TrainSession::bmf(train, Some(test.clone()), cfg);
        let r = s.run();
        (r, test, dir)
    }

    /// The seed implementation's scalar serving path, replicated from
    /// owned snapshot `Mat`s: per cell, per sample, one `dot` — the
    /// reference the batched engine must reproduce bit-for-bit.
    fn scalar_reference(
        store: &ModelStore,
        view: usize,
        rows: &[u32],
        cols: &[u32],
    ) -> Vec<Prediction> {
        let samples: Vec<Snapshot> =
            (0..store.len()).map(|i| store.load_snapshot(i).unwrap()).collect();
        let off = store.meta().vs_offset(view);
        let offset = store.meta().offsets[view];
        let n = samples.len();
        rows.iter()
            .zip(cols)
            .map(|(&r, &c)| {
                let (mut sum, mut sumsq) = (0.0, 0.0);
                for snap in &samples {
                    let p = dot(snap.u.row(r as usize), snap.vs[off].row(c as usize));
                    sum += p;
                    sumsq += p * p;
                }
                Prediction {
                    mean: sum / n as f64 + offset,
                    std: variance(sum, sumsq, n).sqrt(),
                }
            })
            .collect()
    }

    /// Acceptance (a): a store saved every sampling iteration serves the
    /// same posterior-mean RMSE the train session reported.
    #[test]
    fn served_average_matches_training_rmse() {
        let (r, test, dir) = saved_bmf("parity");
        assert_eq!(r.nsnapshots, 12);
        assert_eq!(r.store_path.as_deref(), Some(dir.as_path()));

        let ps = PredictSession::open(&dir).unwrap();
        assert_eq!(ps.nsamples(), 12);
        let t = TestSet::from_sparse(&test);
        let preds = ps.predict_cells(0, &t.rows, &t.cols);
        let means: Vec<f64> = preds.iter().map(|p| p.mean).collect();
        let rmse = crate::model::rmse(&means, &t.vals);
        assert!(
            (rmse - r.rmse).abs() < 1e-9,
            "served rmse {rmse} vs trained {}",
            r.rmse
        );
        // uncertainty is populated and sane
        assert!(preds.iter().all(|p| p.std.is_finite() && p.std >= 0.0));
        assert!(preds.iter().any(|p| p.std > 0.0));
    }

    /// Tentpole acceptance: on a packed v3 store the batched
    /// `predict_cells` / `predict_cells_mean` / `top_k` return results
    /// bit-identical to the seed per-sample scalar path.
    #[test]
    fn batched_paths_bit_identical_to_scalar_path_on_packed_store() {
        let (_, test, dir) = saved_bmf("batchedbits");
        let mut store = ModelStore::open(&dir).unwrap();
        if !store.is_packed() {
            store.compact().unwrap();
        }
        let store = ModelStore::open(&dir).unwrap();
        assert!(store.is_packed());
        let ps = PredictSession::from_store(&store, 3).unwrap();
        #[cfg(all(unix, target_endian = "little", target_pointer_width = "64"))]
        assert!(ps.zero_copy(), "packed store must serve zero-copy on unix");

        let t = TestSet::from_sparse(&test);
        let want = scalar_reference(&store, 0, &t.rows, &t.cols);
        let got = ps.predict_cells(0, &t.rows, &t.cols);
        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.mean.to_bits(), w.mean.to_bits(), "batched mean differs");
            assert_eq!(g.std.to_bits(), w.std.to_bits(), "batched std differs");
        }
        let means = ps.predict_cells_mean(0, &t.rows, &t.cols);
        for (m, w) in means.iter().zip(&want) {
            assert_eq!(m.to_bits(), w.mean.to_bits(), "fast-path mean differs");
        }
        // top_k scores equal the scalar pointwise means, candidates and all
        for row in [0usize, 7, 79] {
            for (col, score) in ps.top_k(0, row, 7, &[]) {
                let w = scalar_reference(&store, 0, &[row as u32], &[col]);
                assert_eq!(score.to_bits(), w[0].mean.to_bits(), "top-k score row {row}");
            }
        }
        // and thread count never changes batched answers
        let ps1 = PredictSession::from_store(&store, 1).unwrap();
        let got1 = ps1.predict_cells(0, &t.rows, &t.cols);
        assert_eq!(got, got1);
        assert_eq!(ps.top_k(0, 5, 10, &[]), ps1.top_k(0, 5, 10, &[]));
    }

    /// Migration invariant: the same store serves bit-identical results
    /// through the snapshot-dir panels and the packed mmap panels.
    #[test]
    fn packed_and_snapshot_dir_models_serve_identically() {
        let dir = scratch("pathpair");
        let mut rng = crate::rng::Rng::new(95);
        let meta = crate::store::StoreMeta {
            num_latent: 5,
            nrows: 12,
            view_dims: vec![vec![9]],
            offsets: vec![0.75],
            save_freq: 1,
            link_features: 0,
            producer: None,
        };
        let mut store = ModelStore::create(&dir, meta).unwrap();
        for it in 1..=4 {
            let mut u = Mat::zeros(12, 5);
            let mut v = Mat::zeros(9, 5);
            rng.fill_normal(u.data_mut());
            rng.fill_normal(v.data_mut());
            store
                .save_snapshot(&Snapshot { iteration: it, u, vs: vec![v], alphas: vec![2.0], link: None })
                .unwrap();
        }
        let unpacked = PredictSession::from_store(&ModelStore::open(&dir).unwrap(), 2).unwrap();
        assert!(!unpacked.zero_copy());
        let mut store = ModelStore::open(&dir).unwrap();
        store.compact().unwrap();
        let packed = PredictSession::from_store(&ModelStore::open(&dir).unwrap(), 2).unwrap();

        let rows: Vec<u32> = (0..40).map(|i| i % 12).collect();
        let cols: Vec<u32> = (0..40).map(|i| (i * 5) % 9).collect();
        assert_eq!(unpacked.predict_cells(0, &rows, &cols), packed.predict_cells(0, &rows, &cols));
        assert_eq!(unpacked.top_k(0, 3, 5, &[]), packed.top_k(0, 3, 5, &[]));
        let (bu, bp) = (unpacked.predict_block(0, 2..9, 1..8), packed.predict_block(0, 2..9, 1..8));
        assert_eq!(bu.mean.max_abs_diff(&bp.mean), 0.0);
        assert_eq!(bu.std.max_abs_diff(&bp.std), 0.0);
        assert_eq!(
            unpacked.predict_coords(0, &[4, 2]),
            packed.predict_coords(0, &[4, 2])
        );
    }

    /// Satellite regression: equal scores must order deterministically
    /// by ascending column index — inside the list and at the K
    /// boundary (never heap iteration order).
    #[test]
    fn top_k_breaks_score_ties_by_column_index() {
        let dir = scratch("ties");
        let meta = crate::store::StoreMeta {
            num_latent: 2,
            nrows: 1,
            view_dims: vec![vec![6]],
            offsets: vec![0.0],
            save_freq: 1,
            link_features: 0,
            producer: None,
        };
        let mut store = ModelStore::create(&dir, meta).unwrap();
        // u = [1, 0]; column scores: 1, 2, 1, 2, 0.5, 2  (deliberate ties)
        let u = Mat::from_vec(1, 2, vec![1.0, 0.0]);
        let v = Mat::from_vec(
            6,
            2,
            vec![1.0, 9.0, 2.0, 9.0, 1.0, 9.0, 2.0, 9.0, 0.5, 9.0, 2.0, 9.0],
        );
        store
            .save_snapshot(&Snapshot { iteration: 1, u, vs: vec![v], alphas: vec![1.0], link: None })
            .unwrap();
        store.compact().unwrap();
        let ps = PredictSession::from_store(&store, 1).unwrap();
        // ties at 2.0 (cols 1, 3, 5) list in ascending column order
        assert_eq!(ps.top_k(0, 0, 4, &[]), vec![(1, 2.0), (3, 2.0), (5, 2.0), (0, 1.0)]);
        // K boundary inside a tie group keeps the smaller columns
        assert_eq!(ps.top_k(0, 0, 2, &[]), vec![(1, 2.0), (3, 2.0)]);
        // boundary tie across the second group: cols 0 and 2 tie at 1.0
        assert_eq!(ps.top_k(0, 0, 5, &[]), vec![(1, 2.0), (3, 2.0), (5, 2.0), (0, 1.0), (2, 1.0)]);
        assert_eq!(
            ps.top_k(0, 0, 4, &[1]),
            vec![(3, 2.0), (5, 2.0), (0, 1.0), (2, 1.0)],
            "exclusion keeps deterministic tie order"
        );
        // the tensor-mode selector shares the tie rules
        assert_eq!(ps.top_k_mode(0, &[0, 0], 1, 4, &[]), ps.top_k(0, 0, 4, &[]));
    }

    /// Acceptance (b): top-K agrees with pointwise scoring — same values,
    /// and genuinely the K best.
    #[test]
    fn top_k_is_consistent_with_pointwise_scores() {
        let (_, _, dir) = saved_bmf("topk");
        let ps = PredictSession::open(&dir).unwrap();
        let user = 7;
        let k = 5;
        let top = ps.top_k(0, user, k, &[]);
        assert_eq!(top.len(), k);
        // scores descend and match predict_one exactly
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        for &(col, score) in &top {
            let p = ps.predict_one(0, user, col as usize);
            assert_eq!(score, p.mean, "top-k score must equal pointwise mean");
        }
        // nothing outside the list beats the list's minimum
        let floor = top.last().unwrap().1;
        let in_list: std::collections::HashSet<u32> = top.iter().map(|t| t.0).collect();
        for j in 0..ps.ncols(0) {
            if !in_list.contains(&(j as u32)) {
                assert!(ps.predict_one(0, user, j).mean <= floor);
            }
        }
        // exclusion removes items from the candidate set
        let excl: Vec<u32> = top.iter().map(|t| t.0).collect();
        let top2 = ps.top_k(0, user, k, &excl);
        assert!(top2.iter().all(|t| !in_list.contains(&t.0)));
        assert!(top2.first().unwrap().1 <= floor);
    }

    /// The tensor serving APIs collapse to the two-sided ones on 2-mode
    /// views — bit-for-bit, because the Hadamard-dot replays `dot`.
    #[test]
    fn tensor_apis_agree_with_two_sided_on_matrix_stores() {
        let (_, _, dir) = saved_bmf("tensorapi");
        let ps = PredictSession::open(&dir).unwrap();
        assert_eq!(ps.nmodes(0), 2);
        assert_eq!(ps.mode_dims(0), vec![ps.nrows(), ps.ncols(0)]);
        let p = ps.predict_one(0, 4, 9);
        let pc = ps.predict_coords(0, &[4, 9]);
        assert_eq!(p, pc);
        let t1 = ps.top_k(0, 4, 5, &[]);
        let t2 = ps.top_k_mode(0, &[4, 0], 1, 5, &[]);
        assert_eq!(t1, t2);
        // exclusion behaves identically too
        let excl: Vec<u32> = t1.iter().map(|t| t.0).collect();
        assert_eq!(ps.top_k(0, 4, 3, &excl), ps.top_k_mode(0, &[4, 0], 1, 3, &excl));
    }

    #[test]
    fn block_prediction_matches_pointwise() {
        let (_, _, dir) = saved_bmf("block");
        let ps = PredictSession::open_with_threads(&dir, 3).unwrap();
        let blk = ps.predict_block(0, 5..15, 3..9);
        assert_eq!((blk.mean.rows(), blk.mean.cols()), (10, 6));
        for bi in 0..10 {
            for bj in 0..6 {
                let p = ps.predict_one(0, 5 + bi, 3 + bj);
                assert!(
                    (blk.mean[(bi, bj)] - p.mean).abs() < 1e-9,
                    "mean mismatch at ({bi},{bj})"
                );
                assert!((blk.std[(bi, bj)] - p.std).abs() < 1e-9);
            }
        }
        // thread count must not change block results
        let ps1 = PredictSession::open_with_threads(&dir, 1).unwrap();
        let blk1 = ps1.predict_block(0, 5..15, 3..9);
        assert_eq!(blk.mean.max_abs_diff(&blk1.mean), 0.0);
        assert_eq!(blk.std.max_abs_diff(&blk1.std), 0.0);
    }

    /// Acceptance (c): out-of-matrix Macau prediction for rows held out
    /// of training beats the global-mean baseline.
    #[test]
    fn out_of_matrix_beats_global_mean() {
        let d = crate::data::chembl_synth(&crate::data::ChemblSpec {
            compounds: 100,
            proteins: 30,
            nnz: 3_000,
            fp_bits: 64,
            fp_density: 8,
            seed: 52,
            ..Default::default()
        });
        // hold rows 0..5 out of training entirely
        const HELD: u32 = 5;
        let all: Vec<(u32, u32, f64)> = d.activity.triplets().collect();
        let train: Vec<_> = all.iter().copied().filter(|t| t.0 >= HELD).collect();
        let held: Vec<_> = all.iter().copied().filter(|t| t.0 < HELD).collect();
        assert!(held.len() >= 5, "need held-out cells, got {}", held.len());
        let train_m =
            SparseMatrix::from_triplets(d.activity.nrows(), d.activity.ncols(), train);

        let dir = scratch("oom");
        let cfg = SessionConfig {
            num_latent: 4,
            burnin: 15,
            nsamples: 20,
            seed: 52,
            threads: 2,
            save_freq: 2,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = SessionBuilder::new(cfg)
            .row_macau(d.fingerprints_sparse.clone())
            .add_view(
                MatrixConfig::SparseUnknown(train_m.clone()),
                NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 },
                None,
            )
            .build();
        let r = s.run();
        assert_eq!(r.nsnapshots, 10);

        let ps = PredictSession::open(&dir).unwrap();
        assert!(ps.has_link());
        let mut feats = vec![0.0; 64];
        let mut preds = Vec::new();
        let mut truth = Vec::new();
        for row in 0..HELD {
            let cols: Vec<u32> =
                held.iter().filter(|t| t.0 == row).map(|t| t.1).collect();
            if cols.is_empty() {
                continue;
            }
            d.fingerprints_sparse.row_dense(row as usize, &mut feats);
            for p in ps.predict_new_row(&feats, 0, &cols).unwrap() {
                preds.push(p.mean);
            }
            truth.extend(held.iter().filter(|t| t.0 == row).map(|t| t.2));
        }
        let rmse_oom = crate::model::rmse(&preds, &truth);
        let global_mean = train_m.mean_value();
        let rmse_mean = crate::model::rmse(&vec![global_mean; truth.len()], &truth);
        assert!(
            rmse_oom < rmse_mean,
            "out-of-matrix rmse {rmse_oom} must beat global-mean {rmse_mean}"
        );
    }

    #[test]
    fn new_row_requires_link_and_matching_features() {
        let (_, _, dir) = saved_bmf("nolink");
        let ps = PredictSession::open(&dir).unwrap();
        assert!(!ps.has_link());
        assert!(ps.predict_new_row(&[0.0; 8], 0, &[1]).is_err());
    }

    #[test]
    fn open_rejects_manifest_payload_mismatch() {
        // a hand-built (never compacted) store with one sample's U
        // clobbered by a wrong-shape payload: opening must error instead
        // of serving out-of-bounds reads later
        let dir = scratch("corrupt");
        let meta = crate::store::StoreMeta {
            num_latent: 3,
            nrows: 6,
            view_dims: vec![vec![4]],
            offsets: vec![0.0],
            save_freq: 1,
            link_features: 0,
            producer: None,
        };
        let mut store = ModelStore::create(&dir, meta).unwrap();
        let mut rng = crate::rng::Rng::new(96);
        let mut u = Mat::zeros(6, 3);
        let mut v = Mat::zeros(4, 3);
        rng.fill_normal(u.data_mut());
        rng.fill_normal(v.data_mut());
        store
            .save_snapshot(&Snapshot { iteration: 1, u, vs: vec![v], alphas: vec![1.0], link: None })
            .unwrap();
        crate::sparse::io::write_dbm(&Mat::zeros(3, 3), &dir.join("sample_00001/u.dbm")).unwrap();
        let err = PredictSession::open(&dir).unwrap_err().to_string();
        assert!(err.contains("manifest says"), "{err}");
    }

    #[test]
    fn manifest_claiming_missing_packs_falls_back_to_snapshot_dirs() {
        // crash-window recovery: save_snapshot deletes packed/ before
        // the manifest rename lands; a manifest still claiming the
        // artifact over intact sample dirs must serve, not brick
        let (_, _, dir) = saved_bmf("packgone");
        let store = ModelStore::open(&dir).unwrap();
        assert!(store.is_packed());
        let want = {
            let ps = PredictSession::from_store(&store, 1).unwrap();
            ps.predict_one(0, 2, 3)
        };
        std::fs::remove_dir_all(dir.join("packed")).unwrap();
        let ps = PredictSession::open_with_threads(&dir, 1).unwrap();
        assert!(!ps.zero_copy(), "must have served from the snapshot dirs");
        assert_eq!(ps.predict_one(0, 2, 3), want);
    }

    #[test]
    fn open_rejects_corrupted_pack_payload() {
        let (_, _, dir) = saved_bmf("packcorrupt");
        let mut store = ModelStore::open(&dir).unwrap();
        if !store.is_packed() {
            store.compact().unwrap();
        }
        // truncate the packed U payload: open must fail loudly, not fall
        // back silently or read out of bounds
        let upath = crate::store::packed::u_pack_path(&dir);
        let bytes = std::fs::read(&upath).unwrap();
        std::fs::write(&upath, &bytes[..bytes.len() - 16]).unwrap();
        let err = PredictSession::open(&dir).unwrap_err().to_string();
        assert!(err.contains("truncated or size-mismatched"), "{err}");
    }

    #[test]
    fn single_sample_store_has_zero_std() {
        let (train, _) = crate::data::movielens_like(30, 20, 400, 0.0, 53);
        let dir = scratch("one");
        let cfg = SessionConfig {
            num_latent: 3,
            burnin: 2,
            nsamples: 1,
            threads: 1,
            save_freq: 1,
            save_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut s = TrainSession::bmf(train, None, cfg);
        let r = s.run();
        assert_eq!(r.nsnapshots, 1);
        let ps = PredictSession::open(&dir).unwrap();
        let p = ps.predict_one(0, 0, 0);
        assert_eq!(p.std, 0.0);
        assert!(p.mean.is_finite());
    }

    #[test]
    fn truncate_and_hot_swap_share_the_pool() {
        let (_, _, dir) = saved_bmf("swap");
        let mut ps = PredictSession::open_with_threads(&dir, 2).unwrap();
        ps.truncate_samples(3);
        assert_eq!(ps.nsamples(), 3);
        ps.truncate_samples(0);
        assert_eq!(ps.nsamples(), 1, "always keeps one sample");
        ps.truncate_samples(10_000);
        assert_eq!(ps.nsamples(), 12);
        // hot swap: a new model over the same store serves all samples
        // and identical answers through the shared pool
        let swapped = ps.with_model(Arc::new(ServingModel::load(&dir).unwrap()));
        assert_eq!(swapped.nsamples(), 12);
        assert_eq!(swapped.predict_one(0, 2, 3), ps.predict_one(0, 2, 3));
    }
}
