//! Dense linear-algebra substrate (the paper's Eigen + MKL/OpenBLAS role).
//!
//! Everything SMURFF's Gibbs sweeps need: a row-major `f64` matrix type,
//! matrix/vector products, symmetric rank-k updates, Cholesky,
//! triangular solves and a conjugate-gradient solver (for the Macau link
//! matrix).  The hot kernels have three implementations behind a runtime
//! [`Backend`] switch — `Blocked` (tiled scalar, unroll-friendly; stands
//! in for MKL), `Naive` (textbook loops; stands in for a generic
//! OpenBLAS build), and `Simd` (explicit `std::arch` AVX2+FMA / NEON
//! kernels in [`simd`], runtime-feature-detected) — the axis swept by
//! the Figure-5 benchmark and the ISSUE 8 scalar-vs-SIMD tables.
//!
//! Reproducibility: `Blocked` and `Naive` are the seed-identical scalar
//! family; `Simd` is tolerance-equivalent (see [`simd`]'s module docs
//! for the contract) and is masked back to `Blocked` by
//! [`simd::set_strict`].  Each dispatching wrapper here keeps its exact
//! seed arithmetic available as a `*_scalar` twin.

mod cg;
mod chol;
mod gemm;
pub mod simd;

pub use cg::cg_solve;
pub use chol::{
    chol_inplace, chol_solve, tri_solve_lower, tri_solve_lower_into, tri_solve_lower_into_scalar,
    tri_solve_upper_t, tri_solve_upper_t_into, tri_solve_upper_t_into_scalar, Chol,
};
pub use gemm::{
    gemm, gemm_into, gemm_ref, gemm_ref_into, gemm_tn, gemm_tn_with, matvec, matvec_t,
    matvec_t_ref, syrk, Backend,
};

/// True when the process-wide [`Backend`] dispatches to the vector
/// kernels right now (strict mode and missing CPU features both read
/// as `false`).
#[inline]
pub fn simd_enabled() -> bool {
    Backend::global() == Backend::Simd
}

use std::fmt;

/// Dense row-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Identity scaled by `v`.
    pub fn eye_scaled(n: usize, v: f64) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = v;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Two disjoint mutable rows (for swap-free updates).
    pub fn rows_mut2(&mut self, i: usize, j: usize) -> (&mut [f64], &mut [f64]) {
        assert_ne!(i, j);
        let c = self.cols;
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        let (a, b) = self.data.split_at_mut(hi * c);
        let ra = &mut a[lo * c..(lo + 1) * c];
        let rb = &mut b[..c];
        if i < j {
            (ra, rb)
        } else {
            (rb, ra)
        }
    }

    /// Cache-blocked tiled transpose.  The naive strided column walk
    /// touches `cols` distinct destination cache lines per source row;
    /// walking 32×32 tiles keeps both the source rows and the
    /// destination columns of a tile resident, which matters for the
    /// dense side-info views materialized once per session build.
    pub fn transpose(&self) -> Mat {
        const TB: usize = 32;
        let mut t = Mat::zeros(self.cols, self.rows);
        let (r, c) = (self.rows, self.cols);
        for i0 in (0..r).step_by(TB) {
            let i1 = (i0 + TB).min(r);
            for j0 in (0..c).step_by(TB) {
                let j1 = (j0 + TB).min(c);
                for i in i0..i1 {
                    let src = &self.data[i * c..(i + 1) * c];
                    for j in j0..j1 {
                        t.data[j * r + i] = src[j];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn scale(&mut self, s: f64) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// self += s * other
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += s * b;
        }
    }

    /// Symmetrize in place: (A + A^T) / 2.  Used after accumulating
    /// near-symmetric sums to kill round-off drift before Cholesky.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in i + 1..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

/// Borrowed row-major matrix view over a contiguous `f64` slice — the
/// zero-copy sibling of [`Mat`].  The packed serving artifact hands out
/// `MatRef`s over its mmap'd sample-major factor blocks, and the gemm /
/// batched-dot kernels accept them directly, so prediction never clones
/// a factor matrix (ISSUE 5 tentpole).  Bit-compatibility: every kernel
/// taking a `MatRef` runs the exact arithmetic of its `Mat` twin (the
/// `Mat` entry points are thin wrappers over the `MatRef` ones).
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a> {
    rows: usize,
    cols: usize,
    data: &'a [f64],
}

impl<'a> MatRef<'a> {
    pub fn new(rows: usize, cols: usize, data: &'a [f64]) -> MatRef<'a> {
        assert_eq!(data.len(), rows * cols, "MatRef shape mismatch");
        MatRef { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &'a [f64] {
        self.data
    }

    #[inline]
    pub fn row(&self, i: usize) -> &'a [f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Owned copy (materializes the view; used by the store migration
    /// path, never by the serving hot loops).
    pub fn to_mat(&self) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.to_vec())
    }
}

impl Mat {
    /// Borrow this matrix as a [`MatRef`].
    #[inline]
    pub fn view(&self) -> MatRef<'_> {
        MatRef { rows: self.rows, cols: self.cols, data: &self.data }
    }
}

impl std::ops::Index<(usize, usize)> for MatRef<'_> {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

/// Dot product, dispatching on the global [`Backend`] (`Simd` → the
/// vector kernel, anything else → the seed-identical scalar one).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    if simd_enabled() {
        simd::dot(a, b)
    } else {
        dot_scalar(a, b)
    }
}

/// Scalar dot product (the seed arithmetic, bit-stable across PRs).
#[inline]
pub fn dot_scalar(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation — autovectorizes well and is more
    // accurate than a single serial accumulator.
    let mut s = [0.0f64; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        s[0] += a[i] * b[i];
        s[1] += a[i + 1] * b[i + 1];
        s[2] += a[i + 2] * b[i + 2];
        s[3] += a[i + 3] * b[i + 3];
    }
    let mut rest = 0.0;
    for i in chunks * 4..a.len() {
        rest += a[i] * b[i];
    }
    s[0] + s[1] + s[2] + s[3] + rest
}

/// Batched dot kernel of the serving engine: `out[j] += dot(x, a.row(j))`
/// for every row `j` of `a` — one contiguous pass over a sample-major
/// factor panel instead of a scalar `dot` call per (sample, cell).
///
/// Register-blocks 4 panel rows per sweep (x stays live across the four
/// outputs), but each output keeps its own 4-lane accumulator set walked
/// in [`dot`]'s exact chunk order, so every `out[j]` is **bit-identical**
/// to `dot(x, a.row(j))` — the contract that lets the batched
/// `PredictSession` paths reproduce the per-sample scalar path to the
/// last ulp (property-tested below).  The contract is ISA-uniform: the
/// `Simd` backend routes to [`simd::dots_into`], which runs
/// [`simd::dot`]'s exact reduction per row.
pub fn dots_into(x: &[f64], a: MatRef<'_>, out: &mut [f64]) {
    if simd_enabled() {
        simd::dots_into(x, a, out)
    } else {
        dots_into_scalar(x, a, out)
    }
}

/// Scalar twin of [`dots_into`] (the seed arithmetic).
pub fn dots_into_scalar(x: &[f64], a: MatRef<'_>, out: &mut [f64]) {
    let k = x.len();
    debug_assert_eq!(a.cols(), k);
    debug_assert_eq!(a.rows(), out.len());
    let chunks = k / 4;
    let mut j = 0;
    while j + 4 <= a.rows() {
        let (r0, r1, r2, r3) = (a.row(j), a.row(j + 1), a.row(j + 2), a.row(j + 3));
        let mut s0 = [0.0f64; 4];
        let mut s1 = [0.0f64; 4];
        let mut s2 = [0.0f64; 4];
        let mut s3 = [0.0f64; 4];
        for c in 0..chunks {
            let i = c * 4;
            for l in 0..4 {
                s0[l] += x[i + l] * r0[i + l];
                s1[l] += x[i + l] * r1[i + l];
                s2[l] += x[i + l] * r2[i + l];
                s3[l] += x[i + l] * r3[i + l];
            }
        }
        let (mut t0, mut t1, mut t2, mut t3) = (0.0, 0.0, 0.0, 0.0);
        for i in chunks * 4..k {
            t0 += x[i] * r0[i];
            t1 += x[i] * r1[i];
            t2 += x[i] * r2[i];
            t3 += x[i] * r3[i];
        }
        out[j] += s0[0] + s0[1] + s0[2] + s0[3] + t0;
        out[j + 1] += s1[0] + s1[1] + s1[2] + s1[3] + t1;
        out[j + 2] += s2[0] + s2[1] + s2[2] + s2[3] + t2;
        out[j + 3] += s3[0] + s3[1] + s3[2] + s3[3] + t3;
        j += 4;
    }
    while j < a.rows() {
        out[j] += dot_scalar(x, a.row(j));
        j += 1;
    }
}

/// y += s * x, dispatching on the global [`Backend`].
#[inline]
pub fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
    if simd_enabled() {
        simd::axpy(y, s, x)
    } else {
        axpy_scalar(y, s, x)
    }
}

/// Scalar twin of [`axpy`] (the seed arithmetic).
#[inline]
pub fn axpy_scalar(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += s * xi;
    }
}

/// Outer-product accumulate: A += s * x x^T (A is n×n row-major).
///
/// This is the innermost operation of the Gibbs sweep (called once per
/// observed rating), so it honours the [`Backend`] switch: `Blocked`
/// runs the contiguous row-sliced form the autovectorizer likes (the
/// MKL-like path of Figure 5); `Naive` runs the strided element-indexed
/// form a generic unblocked BLAS build degrades to.
#[inline]
pub fn ger_sym(a: &mut Mat, s: f64, x: &[f64]) {
    ger_sym_with(a, s, x, Backend::global())
}

/// [`ger_sym`] with an explicit backend (bench/test entry point).
#[inline]
pub fn ger_sym_with(a: &mut Mat, s: f64, x: &[f64], backend: Backend) {
    match backend {
        Backend::Blocked => ger_sym_blocked(a, s, x),
        Backend::Naive => ger_sym_naive(a, s, x),
        Backend::Simd => {
            let n = x.len();
            debug_assert_eq!(a.rows(), n);
            for i in 0..n {
                simd::axpy(a.row_mut(i), s * x[i], x);
            }
        }
    }
}

#[inline]
pub fn ger_sym_blocked(a: &mut Mat, s: f64, x: &[f64]) {
    let n = x.len();
    debug_assert_eq!(a.rows(), n);
    for i in 0..n {
        let sxi = s * x[i];
        let row = a.row_mut(i);
        for j in 0..n {
            row[j] += sxi * x[j];
        }
    }
}

#[inline]
pub fn ger_sym_naive(a: &mut Mat, s: f64, x: &[f64]) {
    let n = x.len();
    debug_assert_eq!(a.rows(), n);
    // column-major sweep over a row-major matrix: strided writes, no
    // vectorizable inner loop — the generic-BLAS cost model
    for j in 0..n {
        for i in 0..n {
            a[(i, j)] += s * x[i] * x[j];
        }
    }
}

/// Upper-triangle-only rank-1 update (BLAS `dsyr`): A[i][j..] += s·x_i·x_j
/// for j ≥ i.  Half the flops of [`ger_sym`]; callers mirror once at the
/// end via [`mirror_upper_to_lower`].  This is the §Perf hot-path form
/// used by the row sampler (EXPERIMENTS.md §Perf, change #1).
#[inline]
pub fn ger_sym_upper(a: &mut Mat, s: f64, x: &[f64]) {
    ger_sym_upper_with(a, s, x, Backend::global())
}

/// [`ger_sym_upper`] with an explicit backend (the sweep passes its
/// per-session snapshot; benches and tests pin a family without
/// touching the process global).
#[inline]
pub fn ger_sym_upper_with(a: &mut Mat, s: f64, x: &[f64], backend: Backend) {
    let n = x.len();
    debug_assert_eq!(a.rows(), n);
    match backend {
        Backend::Blocked => {
            for i in 0..n {
                let sxi = s * x[i];
                let row = &mut a.row_mut(i)[i..];
                for (rj, &xj) in row.iter_mut().zip(&x[i..]) {
                    *rj += sxi * xj;
                }
            }
        }
        Backend::Naive => {
            for j in 0..n {
                for i in 0..=j {
                    a[(i, j)] += s * x[i] * x[j];
                }
            }
        }
        Backend::Simd => {
            for i in 0..n {
                simd::axpy(&mut a.row_mut(i)[i..], s * x[i], &x[i..]);
            }
        }
    }
}

/// Copy the upper triangle onto the lower one (finishing a sequence of
/// [`ger_sym_upper`] updates so Cholesky can read the lower triangle).
#[inline]
pub fn mirror_upper_to_lower(a: &mut Mat) {
    let n = a.rows();
    debug_assert_eq!(n, a.cols());
    for i in 0..n {
        for j in i + 1..n {
            a[(j, i)] = a[(i, j)];
        }
    }
}

/// Fused Gram + RHS accumulation over a *gathered* batch of rows — the
/// Rust analogue of the Layer-1 Pallas kernel and the §Perf hot-path
/// form (EXPERIMENTS.md §Perf, change #2):
///
///   A(upper) += α Σ_t x_t x_tᵀ,     rhs += α Σ_t v_t x_t
///
/// `xs` holds `vals.len()` rows of length k contiguously.  Rank-4
/// blocking keeps 4 source rows live per sweep of A, quadrupling the
/// arithmetic per cache line of A and lengthening the inner loop the
/// autovectorizer sees.  Callers mirror A afterwards.  Dispatches on
/// the global [`Backend`]; the sweep hot path instead picks
/// [`simd::gram_rhs_rank4`] / [`gram_rhs_rank4_scalar`] directly from
/// its per-session snapshot.
pub fn gram_rhs_rank4(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    if simd_enabled() {
        simd::gram_rhs_rank4(a, rhs, alpha, xs, vals)
    } else {
        gram_rhs_rank4_scalar(a, rhs, alpha, xs, vals)
    }
}

/// Scalar twin of [`gram_rhs_rank4`] (the seed arithmetic).
pub fn gram_rhs_rank4_scalar(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    let k = rhs.len();
    debug_assert_eq!(a.rows(), k);
    debug_assert_eq!(xs.len(), vals.len() * k);
    let nnz = vals.len();
    let mut t = 0;
    while t + 4 <= nnz {
        let x0 = &xs[t * k..(t + 1) * k];
        let x1 = &xs[(t + 1) * k..(t + 2) * k];
        let x2 = &xs[(t + 2) * k..(t + 3) * k];
        let x3 = &xs[(t + 3) * k..(t + 4) * k];
        for i in 0..k {
            let a0 = alpha * x0[i];
            let a1 = alpha * x1[i];
            let a2 = alpha * x2[i];
            let a3 = alpha * x3[i];
            let row = &mut a.row_mut(i)[i..];
            for (j, rj) in row.iter_mut().enumerate() {
                *rj += a0 * x0[i + j] + a1 * x1[i + j] + a2 * x2[i + j] + a3 * x3[i + j];
            }
        }
        let (v0, v1, v2, v3) = (vals[t], vals[t + 1], vals[t + 2], vals[t + 3]);
        for j in 0..k {
            rhs[j] += alpha * (v0 * x0[j] + v1 * x1[j] + v2 * x2[j] + v3 * x3[j]);
        }
        t += 4;
    }
    while t < nnz {
        let x = &xs[t * k..(t + 1) * k];
        // tail pinned to the Blocked arm: this twin must stay the seed
        // scalar arithmetic no matter what the process global says
        ger_sym_upper_with(a, alpha, x, Backend::Blocked);
        axpy_scalar(rhs, alpha * vals[t], x);
        t += 1;
    }
}

/// Design rows per tile of the cache-blocked Gram path (§Perf PR4).  A
/// tile of `GRAM_TILE_ROWS` × K f64 stays inside L1 for every K we run
/// (32 × 64 × 8 B = 16 KB), so the gather and the syrk both hit hot
/// lines.  **Must stay a multiple of 4**: 4-row groups then align
/// between [`gram_rhs_tile`] called tile-by-tile and
/// [`gram_rhs_rank4`] called on one full gather, which is what makes
/// the two paths bit-identical (property-tested).
pub const GRAM_TILE_ROWS: usize = 32;

/// Tiled syrk-style fused Gram + RHS over one gathered tile — the
/// cache-blocked sibling of [`gram_rhs_rank4`] (§Perf PR4):
///
///   A(upper) += α Σ_t x_t x_tᵀ,     rhs += α Σ_t v_t x_t
///
/// Loop order is i-outer / 4-row-group-middle / j-inner: each output
/// row of A stays register/L1-hot while the whole tile streams past it
/// (a K² × B flop burst over B·K + K² data), instead of re-touching all
/// of A per 4-row group.  Per element the accumulation *order* is
/// identical to [`gram_rhs_rank4`]'s — 4-row group sums in ascending t,
/// then the < 4 tail rows singly — so calling this tile-by-tile with a
/// tile size that is a multiple of 4 produces bit-identical results to
/// one `gram_rhs_rank4` call over the concatenated gather.  That
/// contract holds within each ISA family ([`simd::gram_rhs_tile`]
/// mirrors [`simd::gram_rhs_rank4`] the same way).  Callers mirror A
/// afterwards.
pub fn gram_rhs_tile(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    if simd_enabled() {
        simd::gram_rhs_tile(a, rhs, alpha, xs, vals)
    } else {
        gram_rhs_tile_scalar(a, rhs, alpha, xs, vals)
    }
}

/// Scalar twin of [`gram_rhs_tile`] (the seed arithmetic).
pub fn gram_rhs_tile_scalar(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    let k = rhs.len();
    debug_assert_eq!(a.rows(), k);
    debug_assert_eq!(xs.len(), vals.len() * k);
    let nnz = vals.len();
    let groups = nnz / 4;
    for i in 0..k {
        let row = a.row_mut(i);
        for g in 0..groups {
            let t = g * 4;
            let x0 = &xs[t * k..(t + 1) * k];
            let x1 = &xs[(t + 1) * k..(t + 2) * k];
            let x2 = &xs[(t + 2) * k..(t + 3) * k];
            let x3 = &xs[(t + 3) * k..(t + 4) * k];
            let a0 = alpha * x0[i];
            let a1 = alpha * x1[i];
            let a2 = alpha * x2[i];
            let a3 = alpha * x3[i];
            for (j, rj) in row[i..].iter_mut().enumerate() {
                *rj += a0 * x0[i + j] + a1 * x1[i + j] + a2 * x2[i + j] + a3 * x3[i + j];
            }
        }
        for t in groups * 4..nnz {
            let x = &xs[t * k..(t + 1) * k];
            // same expression shape as ger_sym_upper's Blocked arm
            let sxi = alpha * x[i];
            for (rj, &xj) in row[i..].iter_mut().zip(&x[i..]) {
                *rj += sxi * xj;
            }
        }
    }
    for g in 0..groups {
        let t = g * 4;
        let x0 = &xs[t * k..(t + 1) * k];
        let x1 = &xs[(t + 1) * k..(t + 2) * k];
        let x2 = &xs[(t + 2) * k..(t + 3) * k];
        let x3 = &xs[(t + 3) * k..(t + 4) * k];
        let (v0, v1, v2, v3) = (vals[t], vals[t + 1], vals[t + 2], vals[t + 3]);
        for j in 0..k {
            rhs[j] += alpha * (v0 * x0[j] + v1 * x1[j] + v2 * x2[j] + v3 * x3[j]);
        }
    }
    for t in groups * 4..nnz {
        axpy_scalar(rhs, alpha * vals[t], &xs[t * k..(t + 1) * k]);
    }
}

/// [`gram_rhs_tile`] driven over a full gather in [`GRAM_TILE_ROWS`]
/// strides — the canonical tile chunking, bit-identical to one
/// [`gram_rhs_rank4`] call over the same gather.  The sweep's hot path
/// streams tiles as it gathers instead of calling this, but tests and
/// benches use it so the chunking convention lives in one place.
pub fn gram_rhs_tiled(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    let k = rhs.len();
    let nnz = vals.len();
    let mut t0 = 0;
    while t0 < nnz {
        let t1 = (t0 + GRAM_TILE_ROWS).min(nnz);
        gram_rhs_tile(a, rhs, alpha, &xs[t0 * k..t1 * k], &vals[t0..t1]);
        t0 = t1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_rows() {
        let mut m = Mat::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
    }

    #[test]
    fn transpose_round_trip() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t[(0, 1)], 4.0);
        assert_eq!(t.transpose(), m);
    }

    #[test]
    fn tiled_transpose_matches_naive_walk_on_odd_shapes() {
        // shapes straddle the 32-tile boundary in both dimensions
        let mut rng = crate::rng::Rng::new(41);
        for (r, c) in [(1usize, 1usize), (7, 3), (31, 33), (32, 32), (33, 65), (100, 1)] {
            let mut m = Mat::zeros(r, c);
            rng.fill_normal(m.data_mut());
            let t = m.transpose();
            assert_eq!((t.rows(), t.cols()), (c, r));
            for i in 0..r {
                for j in 0..c {
                    assert_eq!(t[(j, i)].to_bits(), m[(i, j)].to_bits(), "{r}x{c} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn eye_and_scale() {
        let mut m = Mat::eye(3);
        m.scale(2.0);
        assert_eq!(m, Mat::eye_scaled(3, 2.0));
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (13 - i) as f64).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn mat_ref_views_share_data() {
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let v = m.view();
        assert_eq!((v.rows(), v.cols()), (2, 3));
        assert_eq!(v.row(1), m.row(1));
        assert_eq!(v[(0, 2)], 3.0);
        assert_eq!(v.to_mat(), m);
        // a view over a sub-slice (one "sample block" of a packed panel)
        let blk = MatRef::new(1, 3, &m.data()[3..6]);
        assert_eq!(blk.row(0), &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn dots_into_is_bit_identical_to_dot_per_row() {
        // the batched-serving contract: every out[j] must equal
        // dot(x, row_j) to the last bit, for all k chunk shapes and for
        // panel heights exercising both the 4-row blocks and the tail
        let mut rng = crate::rng::Rng::new(29);
        for (rows, k) in [(1usize, 3usize), (4, 8), (5, 16), (7, 5), (12, 17), (33, 64)] {
            let mut panel = Mat::zeros(rows, k);
            let mut x = vec![0.0; k];
            rng.fill_normal(panel.data_mut());
            rng.fill_normal(&mut x);
            // each ISA family holds the contract internally; pinning the
            // scalar twins keeps this test immune to global-backend
            // changes from concurrently running tests (the SIMD pair is
            // property-tested in linalg::simd)
            let mut out = vec![0.25; rows];
            dots_into_scalar(&x, panel.view(), &mut out);
            for j in 0..rows {
                let want = 0.25 + dot_scalar(&x, panel.row(j));
                assert_eq!(out[j].to_bits(), want.to_bits(), "rows={rows} k={k} j={j}");
            }
            // and the dispatcher always lands on one of the two families
            let mut disp = vec![0.25; rows];
            dots_into(&x, panel.view(), &mut disp);
            for j in 0..rows {
                let scalar = 0.25 + dot_scalar(&x, panel.row(j));
                let vector = 0.25 + simd::dot(&x, panel.row(j));
                assert!(
                    disp[j].to_bits() == scalar.to_bits() || disp[j].to_bits() == vector.to_bits(),
                    "dispatch rows={rows} k={k} j={j}"
                );
            }
        }
    }

    #[test]
    fn gemm_ref_matches_gemm_bitwise() {
        // owned vs borrowed entry points run identical arithmetic for
        // every backend — pinned per call, no process-global flips
        let mut rng = crate::rng::Rng::new(31);
        for backend in [Backend::Blocked, Backend::Naive, Backend::Simd] {
            let mut a = Mat::zeros(9, 6);
            let mut b = Mat::zeros(6, 11);
            rng.fill_normal(a.data_mut());
            rng.fill_normal(b.data_mut());
            let mut owned = Mat::zeros(9, 11);
            gemm_into(&a, &b, &mut owned, backend);
            let mut borrowed = Mat::zeros(9, 11);
            gemm_ref_into(a.view(), b.view(), &mut borrowed, backend);
            assert_eq!(owned.max_abs_diff(&borrowed), 0.0, "{backend:?}");
            // matvec_t twins dispatch internally; adjacent calls agree
            // within the cross-ISA tolerance whatever the global says
            let yt = matvec_t(&a, &[1.0; 9]);
            let yr = matvec_t_ref(a.view(), &[1.0; 9]);
            for (p, q) in yt.iter().zip(&yr) {
                assert!((p - q).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn ger_sym_accumulates_outer_product() {
        let mut a = Mat::zeros(3, 3);
        ger_sym(&mut a, 2.0, &[1.0, 2.0, 3.0]);
        assert_eq!(a[(0, 0)], 2.0);
        assert_eq!(a[(1, 2)], 12.0);
        assert_eq!(a[(2, 1)], 12.0);
    }

    #[test]
    fn ger_sym_upper_plus_mirror_equals_full() {
        let x: Vec<f64> = (0..7).map(|i| (i as f64) * 0.4 - 1.0).collect();
        for backend in [Backend::Blocked, Backend::Naive, Backend::Simd] {
            let mut full = Mat::eye(7);
            ger_sym_with(&mut full, 2.3, &x, backend);
            ger_sym_with(&mut full, -0.7, &x, backend);
            let mut upper = Mat::eye(7);
            ger_sym_upper_with(&mut upper, 2.3, &x, backend);
            ger_sym_upper_with(&mut upper, -0.7, &x, backend);
            mirror_upper_to_lower(&mut upper);
            assert!(full.max_abs_diff(&upper) < 1e-14, "{backend:?}");
        }
    }

    #[test]
    fn gram_rhs_rank4_matches_rank1() {
        let mut rng = crate::rng::Rng::new(9);
        for (k, nnz) in [(4usize, 1usize), (8, 3), (16, 4), (16, 11), (5, 17)] {
            let mut xs = vec![0.0; nnz * k];
            let mut vals = vec![0.0; nnz];
            rng.fill_normal(&mut xs);
            rng.fill_normal(&mut vals);
            let alpha = 1.7;
            let mut a4 = Mat::eye(k);
            let mut r4 = vec![0.5; k];
            gram_rhs_rank4_scalar(&mut a4, &mut r4, alpha, &xs, &vals);
            mirror_upper_to_lower(&mut a4);
            let mut a1 = Mat::eye(k);
            let mut r1 = vec![0.5; k];
            for t in 0..nnz {
                ger_sym_with(&mut a1, alpha, &xs[t * k..(t + 1) * k], Backend::Blocked);
                axpy_scalar(&mut r1, alpha * vals[t], &xs[t * k..(t + 1) * k]);
            }
            assert!(a4.max_abs_diff(&a1) < 1e-12, "k={k} nnz={nnz}");
            for (x, y) in r4.iter().zip(&r1) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_rhs_tile_is_bit_identical_to_rank4() {
        // the §Perf PR4 contract: tile-by-tile accumulation (tile size a
        // multiple of 4) replays gram_rhs_rank4's per-element order, so
        // results match to the last bit — which is what lets the sweep's
        // nnz threshold pick either path without breaking determinism
        let mut rng = crate::rng::Rng::new(19);
        for (k, nnz) in [(3usize, 1usize), (8, 31), (16, 32), (16, 70), (33, 129), (5, 200)] {
            let mut xs = vec![0.0; nnz * k];
            let mut vals = vec![0.0; nnz];
            rng.fill_normal(&mut xs);
            rng.fill_normal(&mut vals);
            let alpha = 0.9;
            let mut a4 = Mat::eye(k);
            let mut r4 = vec![0.25; k];
            gram_rhs_rank4_scalar(&mut a4, &mut r4, alpha, &xs, &vals);
            let mut at = Mat::eye(k);
            let mut rt = vec![0.25; k];
            let mut t0 = 0;
            while t0 < nnz {
                let t1 = (t0 + GRAM_TILE_ROWS).min(nnz);
                gram_rhs_tile_scalar(&mut at, &mut rt, alpha, &xs[t0 * k..t1 * k], &vals[t0..t1]);
                t0 = t1;
            }
            assert_eq!(a4.max_abs_diff(&at), 0.0, "Λ k={k} nnz={nnz}");
            for (x, y) in r4.iter().zip(&rt) {
                assert_eq!(x.to_bits(), y.to_bits(), "rhs k={k} nnz={nnz}");
            }
            // and both agree with the naive rank-1 accumulation
            let mut a1 = Mat::eye(k);
            let mut r1 = vec![0.25; k];
            for t in 0..nnz {
                ger_sym_with(&mut a1, alpha, &xs[t * k..(t + 1) * k], Backend::Blocked);
                axpy_scalar(&mut r1, alpha * vals[t], &xs[t * k..(t + 1) * k]);
            }
            mirror_upper_to_lower(&mut at);
            assert!(at.max_abs_diff(&a1) < 1e-12, "vs rank-1 k={k} nnz={nnz}");
            for (x, y) in rt.iter().zip(&r1) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_tile_rows_is_a_multiple_of_four() {
        // the bit-compatibility argument above depends on this
        assert_eq!(GRAM_TILE_ROWS % 4, 0);
        assert!(GRAM_TILE_ROWS >= 4);
    }

    #[test]
    fn ger_sym_backends_agree() {
        let x: Vec<f64> = (0..9).map(|i| (i as f64) * 0.3 - 1.0).collect();
        let mut a = Mat::zeros(9, 9);
        let mut b = Mat::zeros(9, 9);
        ger_sym_blocked(&mut a, 1.7, &x);
        ger_sym_naive(&mut b, 1.7, &x);
        assert!(a.max_abs_diff(&b) < 1e-14);
    }

    #[test]
    fn rows_mut2_disjoint() {
        let mut m = Mat::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let (a, b) = m.rows_mut2(2, 0);
        a[0] = 50.0;
        b[1] = 20.0;
        assert_eq!(m[(2, 0)], 50.0);
        assert_eq!(m[(0, 1)], 20.0);
    }

    #[test]
    fn symmetrize_kills_drift() {
        let mut m = Mat::from_vec(2, 2, vec![1.0, 2.0 + 1e-9, 2.0 - 1e-9, 3.0]);
        m.symmetrize();
        assert_eq!(m[(0, 1)], m[(1, 0)]);
        assert!((m[(0, 1)] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_works() {
        let mut y = vec![1.0, 1.0];
        axpy(&mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![7.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Mat::from_vec(2, 2, vec![1.0]);
    }
}
