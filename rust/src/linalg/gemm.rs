//! Matrix products with a runtime backend switch.
//!
//! [`Backend::Blocked`] — cache-tiled with a 4×4-ish unrolled microkernel
//! the compiler autovectorizes: our stand-in for MKL (which dispatches to
//! the best vector ISA at runtime, making the Conda-generic binary as fast
//! as a native build — Figure 5's point).
//! [`Backend::Naive`] — textbook triple loop: our stand-in for a generic
//! unoptimized BLAS build.  The Figure-5 bench sweeps this axis.

use super::{Mat, MatRef};
use std::sync::atomic::{AtomicU8, Ordering};

/// Which gemm/syrk implementation to use.  Global default + per-call
/// override — the bench harness flips the global, the library defaults
/// to Blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Tiled + unrolled (MKL stand-in, "native/dispatching" build).
    Blocked,
    /// Textbook loops (generic OpenBLAS stand-in).
    Naive,
}

static GLOBAL_BACKEND: AtomicU8 = AtomicU8::new(0);

impl Backend {
    pub fn set_global(b: Backend) {
        GLOBAL_BACKEND.store(b as u8, Ordering::Relaxed);
    }

    pub fn global() -> Backend {
        if GLOBAL_BACKEND.load(Ordering::Relaxed) == 0 {
            Backend::Blocked
        } else {
            Backend::Naive
        }
    }
}

const TILE: usize = 64;

/// C = A · B  (alloc-free into `c`; `c` is overwritten).
pub fn gemm_into(a: &Mat, b: &Mat, c: &mut Mat, backend: Backend) {
    gemm_ref_into(a.view(), b.view(), c, backend);
}

/// [`gemm_into`] over borrowed views — the actual kernel.  The serving
/// engine calls this directly on `MatRef`s over the packed artifact's
/// mmap'd factor panels; the `Mat` entry points wrap it, so both paths
/// run the identical arithmetic sequence.
pub fn gemm_ref_into(a: MatRef<'_>, b: MatRef<'_>, c: &mut Mat, backend: Backend) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "gemm out shape");
    c.data_mut().fill(0.0);
    match backend {
        Backend::Naive => {
            // i-k-j order at least keeps B row-contiguous
            for i in 0..a.rows() {
                for k in 0..a.cols() {
                    let aik = a[(i, k)];
                    let brow = b.row(k);
                    let crow = c.row_mut(i);
                    for j in 0..brow.len() {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        Backend::Blocked => {
            let (m, kk, n) = (a.rows(), a.cols(), b.cols());
            for i0 in (0..m).step_by(TILE) {
                let i1 = (i0 + TILE).min(m);
                for k0 in (0..kk).step_by(TILE) {
                    let k1 = (k0 + TILE).min(kk);
                    for j0 in (0..n).step_by(TILE) {
                        let j1 = (j0 + TILE).min(n);
                        for i in i0..i1 {
                            // 2-way k unroll over the tile; inner j loop
                            // is contiguous on both B and C -> vectorizes
                            let mut k = k0;
                            while k + 1 < k1 {
                                let aik0 = a[(i, k)];
                                let aik1 = a[(i, k + 1)];
                                let (bk0, bk1) = (b.row(k), b.row(k + 1));
                                let crow = c.row_mut(i);
                                for j in j0..j1 {
                                    crow[j] += aik0 * bk0[j] + aik1 * bk1[j];
                                }
                                k += 2;
                            }
                            if k < k1 {
                                let aik = a[(i, k)];
                                let bk = b.row(k);
                                let crow = c.row_mut(i);
                                for j in j0..j1 {
                                    crow[j] += aik * bk[j];
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// C = A · B with the global backend.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c, Backend::global());
    c
}

/// C = A^T · B (A is m×n -> C is n×p).  Tiled over the m reduction.
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn inner dim");
    let (m, n, p) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(n, p);
    match Backend::global() {
        Backend::Naive => {
            for i in 0..n {
                for j in 0..p {
                    let mut s = 0.0;
                    for k in 0..m {
                        s += a[(k, i)] * b[(k, j)];
                    }
                    c[(i, j)] = s;
                }
            }
        }
        Backend::Blocked => {
            // rank-1 accumulation over rows of A/B: contiguous everywhere
            for k in 0..m {
                let arow = a.row(k);
                let brow = b.row(k);
                for i in 0..n {
                    let aki = arow[i];
                    if aki == 0.0 {
                        continue;
                    }
                    let crow = c.row_mut(i);
                    for j in 0..p {
                        crow[j] += aki * brow[j];
                    }
                }
            }
        }
    }
    c
}

/// C = A · B over borrowed views, with the global backend.
pub fn gemm_ref(a: MatRef<'_>, b: MatRef<'_>) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_ref_into(a, b, &mut c, Backend::global());
    c
}

/// y = A · x.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| super::dot(a.row(i), x)).collect()
}

/// y = A^T · x.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        super::axpy(&mut y, x[i], a.row(i));
    }
    y
}

/// y = A^T · x over a borrowed view — same accumulation as [`matvec_t`].
pub fn matvec_t_ref(a: MatRef<'_>, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        super::axpy(&mut y, x[i], a.row(i));
    }
    y
}

/// C = A^T · A (n×n symmetric from m×n A), honouring the backend switch.
pub fn syrk(a: &Mat, backend: Backend) -> Mat {
    let (m, n) = (a.rows(), a.cols());
    let mut c = Mat::zeros(n, n);
    match backend {
        Backend::Naive => {
            for i in 0..n {
                for j in i..n {
                    let mut s = 0.0;
                    for k in 0..m {
                        s += a[(k, i)] * a[(k, j)];
                    }
                    c[(i, j)] = s;
                    c[(j, i)] = s;
                }
            }
        }
        Backend::Blocked => {
            for k in 0..m {
                let row = a.row(k);
                for i in 0..n {
                    let aki = row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    let crow = c.row_mut(i);
                    for j in i..n {
                        crow[j] += aki * row[j];
                    }
                }
            }
            // mirror the upper triangle
            for i in 0..n {
                for j in i + 1..n {
                    c[(j, i)] = c[(i, j)];
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_mat(r: usize, c: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    fn gemm_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn backends_agree_with_reference() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 13, 9), (70, 65, 67), (128, 64, 130)] {
            let a = random_mat(m, k, &mut rng);
            let b = random_mat(k, n, &mut rng);
            let want = gemm_naive(&a, &b);
            for backend in [Backend::Naive, Backend::Blocked] {
                let mut c = Mat::zeros(m, n);
                gemm_into(&a, &b, &mut c, backend);
                assert!(c.max_abs_diff(&want) < 1e-9, "{backend:?} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        let mut rng = Rng::new(2);
        for backend in [Backend::Naive, Backend::Blocked] {
            Backend::set_global(backend);
            let a = random_mat(23, 7, &mut rng);
            let b = random_mat(23, 11, &mut rng);
            let want = gemm_naive(&a.transpose(), &b);
            let got = gemm_tn(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-9);
        }
        Backend::set_global(Backend::Blocked);
    }

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn syrk_backends_agree() {
        let mut rng = Rng::new(3);
        let a = random_mat(31, 12, &mut rng);
        let want = gemm_naive(&a.transpose(), &a);
        for backend in [Backend::Naive, Backend::Blocked] {
            let got = syrk(&a, backend);
            assert!(got.max_abs_diff(&want) < 1e-9, "{backend:?}");
            // symmetric
            assert!(got.max_abs_diff(&got.transpose()) < 1e-12);
        }
    }

    #[test]
    fn global_backend_switch() {
        Backend::set_global(Backend::Naive);
        assert_eq!(Backend::global(), Backend::Naive);
        Backend::set_global(Backend::Blocked);
        assert_eq!(Backend::global(), Backend::Blocked);
    }

    #[test]
    #[should_panic]
    fn gemm_checks_shapes() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        gemm(&a, &b);
    }
}
