//! Matrix products with a runtime backend switch.
//!
//! [`Backend::Blocked`] — cache-tiled with a 4×4-ish unrolled microkernel
//! the compiler autovectorizes: our stand-in for MKL (which dispatches to
//! the best vector ISA at runtime, making the Conda-generic binary as fast
//! as a native build — Figure 5's point).
//! [`Backend::Naive`] — textbook triple loop: our stand-in for a generic
//! unoptimized BLAS build.  The Figure-5 bench sweeps this axis.

use super::{Mat, MatRef};
use std::sync::atomic::{AtomicU8, Ordering};

/// Which kernel implementation to use — the one "engine choice" axis
/// (ISSUE 8).  Global default + per-call override — the bench harness
/// flips the global, sessions snapshot it into their [`SweepTuning`]
/// (`crate::coordinator::SweepTuning::backend`), and the library
/// defaults to Blocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Tiled + unrolled scalar f64 (MKL stand-in, "native/dispatching"
    /// build).  The reproducibility anchor: bit-identical to the seed.
    Blocked = 0,
    /// Textbook loops (generic OpenBLAS stand-in).
    Naive = 1,
    /// Explicit `std::arch` vector kernels ([`super::simd`]; AVX2+FMA
    /// on x86_64, NEON on aarch64) over the Blocked layout.  Tolerance-
    /// (not bit-) equivalent to Blocked — see the simd module docs.
    Simd = 2,
}

/// Sentinel meaning "not yet resolved": the first [`Backend::global`]
/// call reads `SMURFF_KERNEL_ISA` and caches the answer.
const BACKEND_UNSET: u8 = u8::MAX;

static GLOBAL_BACKEND: AtomicU8 = AtomicU8::new(BACKEND_UNSET);

impl Backend {
    pub fn set_global(b: Backend) {
        GLOBAL_BACKEND.store(b.sanitized() as u8, Ordering::Relaxed);
    }

    /// The process-wide default backend.  Resolved lazily on first
    /// call: honours the `SMURFF_KERNEL_ISA` environment variable
    /// (`scalar`/`blocked` | `naive` | `simd` | `auto`), defaulting to
    /// `Blocked` — the seed-identical path — when unset.  The answer is
    /// always [`Backend::effective`]: strict mode masks `Simd` back to
    /// `Blocked`.
    pub fn global() -> Backend {
        let mut v = GLOBAL_BACKEND.load(Ordering::Relaxed);
        if v == BACKEND_UNSET {
            let b = Backend::from_env().sanitized();
            // benign race: concurrent first calls resolve identically
            GLOBAL_BACKEND.store(b as u8, Ordering::Relaxed);
            v = b as u8;
        }
        let b = match v {
            1 => Backend::Naive,
            2 => Backend::Simd,
            _ => Backend::Blocked,
        };
        b.effective()
    }

    /// What this backend actually dispatches to right now: `Simd`
    /// degrades to `Blocked` under [`super::simd::strict`] mode or when
    /// the CPU lacks a vector ISA.  Sweep code calls this once per row
    /// on its snapshotted backend.
    #[inline]
    pub fn effective(self) -> Backend {
        if self == Backend::Simd && (super::simd::strict() || !super::simd::available()) {
            Backend::Blocked
        } else {
            self
        }
    }

    /// Downgrade `Simd` to `Blocked` (with a warning) when no vector
    /// ISA is available, so a stored `Simd` always implies the feature
    /// check passed.
    pub fn sanitized(self) -> Backend {
        if self == Backend::Simd && !super::simd::available() {
            crate::log_warn!("SIMD backend requested but this CPU has no AVX2+FMA/NEON; using scalar Blocked");
            Backend::Blocked
        } else {
            self
        }
    }

    /// The best backend for this CPU: `Simd` when a vector ISA is
    /// available, else `Blocked`.
    pub fn detect() -> Backend {
        if super::simd::available() {
            Backend::Simd
        } else {
            Backend::Blocked
        }
    }

    /// Parse a kernel-ISA spec (CLI `--kernel-isa`, `SMURFF_KERNEL_ISA`
    /// env, `--engine native:<isa>` suffix).
    pub fn parse(s: &str) -> Result<Backend, String> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" | "blocked" => Ok(Backend::Blocked),
            "naive" => Ok(Backend::Naive),
            "simd" => Ok(Backend::Simd),
            "auto" => Ok(Backend::detect()),
            other => Err(format!("unknown kernel ISA '{other}' (scalar|naive|simd|auto)")),
        }
    }

    fn from_env() -> Backend {
        match std::env::var("SMURFF_KERNEL_ISA") {
            Ok(s) if !s.is_empty() => Backend::parse(&s).unwrap_or_else(|e| {
                crate::log_warn!("SMURFF_KERNEL_ISA: {e}; using scalar Blocked");
                Backend::Blocked
            }),
            _ => Backend::Blocked,
        }
    }

    /// Short label of the instruction set this backend runs —
    /// "avx2+fma"/"neon" for `Simd`, "scalar" otherwise.  Used by the
    /// bench header, train banner, serve `status`, and the
    /// `smurff_kernel_isa` gauge.
    pub fn isa_label(self) -> &'static str {
        match self.effective() {
            Backend::Simd => super::simd::isa_name(),
            Backend::Blocked | Backend::Naive => "scalar",
        }
    }
}

const TILE: usize = 64;

/// C = A · B  (alloc-free into `c`; `c` is overwritten).
pub fn gemm_into(a: &Mat, b: &Mat, c: &mut Mat, backend: Backend) {
    gemm_ref_into(a.view(), b.view(), c, backend);
}

/// [`gemm_into`] over borrowed views — the actual kernel.  The serving
/// engine calls this directly on `MatRef`s over the packed artifact's
/// mmap'd factor panels; the `Mat` entry points wrap it, so both paths
/// run the identical arithmetic sequence.
pub fn gemm_ref_into(a: MatRef<'_>, b: MatRef<'_>, c: &mut Mat, backend: Backend) {
    assert_eq!(a.cols(), b.rows(), "gemm inner dim");
    assert_eq!((c.rows(), c.cols()), (a.rows(), b.cols()), "gemm out shape");
    c.data_mut().fill(0.0);
    match backend {
        Backend::Naive => {
            // i-k-j order at least keeps B row-contiguous
            for i in 0..a.rows() {
                for k in 0..a.cols() {
                    let aik = a[(i, k)];
                    let brow = b.row(k);
                    let crow = c.row_mut(i);
                    for j in 0..brow.len() {
                        crow[j] += aik * brow[j];
                    }
                }
            }
        }
        Backend::Blocked => {
            let (m, kk, n) = (a.rows(), a.cols(), b.cols());
            for i0 in (0..m).step_by(TILE) {
                let i1 = (i0 + TILE).min(m);
                for k0 in (0..kk).step_by(TILE) {
                    let k1 = (k0 + TILE).min(kk);
                    for j0 in (0..n).step_by(TILE) {
                        let j1 = (j0 + TILE).min(n);
                        for i in i0..i1 {
                            // 2-way k unroll over the tile; inner j loop
                            // is contiguous on both B and C -> vectorizes
                            let mut k = k0;
                            while k + 1 < k1 {
                                let aik0 = a[(i, k)];
                                let aik1 = a[(i, k + 1)];
                                let (bk0, bk1) = (b.row(k), b.row(k + 1));
                                let crow = c.row_mut(i);
                                for j in j0..j1 {
                                    crow[j] += aik0 * bk0[j] + aik1 * bk1[j];
                                }
                                k += 2;
                            }
                            if k < k1 {
                                let aik = a[(i, k)];
                                let bk = b.row(k);
                                let crow = c.row_mut(i);
                                for j in j0..j1 {
                                    crow[j] += aik * bk[j];
                                }
                            }
                        }
                    }
                }
            }
        }
        Backend::Simd => {
            // Blocked's exact tiling with the explicit-FMA microkernel
            // on the contiguous j span (tolerance-, not bit-, equal).
            let (m, kk, n) = (a.rows(), a.cols(), b.cols());
            for i0 in (0..m).step_by(TILE) {
                let i1 = (i0 + TILE).min(m);
                for k0 in (0..kk).step_by(TILE) {
                    let k1 = (k0 + TILE).min(kk);
                    for j0 in (0..n).step_by(TILE) {
                        let j1 = (j0 + TILE).min(n);
                        for i in i0..i1 {
                            let mut k = k0;
                            while k + 1 < k1 {
                                let aik0 = a[(i, k)];
                                let aik1 = a[(i, k + 1)];
                                let (bk0, bk1) = (b.row(k), b.row(k + 1));
                                super::simd::fma2_into(
                                    &mut c.row_mut(i)[j0..j1],
                                    aik0,
                                    &bk0[j0..j1],
                                    aik1,
                                    &bk1[j0..j1],
                                );
                                k += 2;
                            }
                            if k < k1 {
                                let aik = a[(i, k)];
                                let bk = b.row(k);
                                super::simd::axpy(&mut c.row_mut(i)[j0..j1], aik, &bk[j0..j1]);
                            }
                        }
                    }
                }
            }
        }
    }
}

/// C = A · B with the global backend.
pub fn gemm(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_into(a, b, &mut c, Backend::global());
    c
}

/// C = A^T · B (A is m×n -> C is n×p) with the global backend.
pub fn gemm_tn(a: &Mat, b: &Mat) -> Mat {
    gemm_tn_with(a, b, Backend::global())
}

/// [`gemm_tn`] with an explicit backend (bench/test entry point).
pub fn gemm_tn_with(a: &Mat, b: &Mat, backend: Backend) -> Mat {
    assert_eq!(a.rows(), b.rows(), "gemm_tn inner dim");
    let (m, n, p) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(n, p);
    match backend {
        Backend::Naive => {
            for i in 0..n {
                for j in 0..p {
                    let mut s = 0.0;
                    for k in 0..m {
                        s += a[(k, i)] * b[(k, j)];
                    }
                    c[(i, j)] = s;
                }
            }
        }
        Backend::Blocked => {
            // rank-1 accumulation over rows of A/B: contiguous everywhere
            for k in 0..m {
                let arow = a.row(k);
                let brow = b.row(k);
                for i in 0..n {
                    let aki = arow[i];
                    if aki == 0.0 {
                        continue;
                    }
                    let crow = c.row_mut(i);
                    for j in 0..p {
                        crow[j] += aki * brow[j];
                    }
                }
            }
        }
        Backend::Simd => {
            // Blocked's rank-1 structure with FMA-lane row updates
            for k in 0..m {
                let arow = a.row(k);
                let brow = b.row(k);
                for i in 0..n {
                    let aki = arow[i];
                    if aki == 0.0 {
                        continue;
                    }
                    super::simd::axpy(c.row_mut(i), aki, brow);
                }
            }
        }
    }
    c
}

/// C = A · B over borrowed views, with the global backend.
pub fn gemm_ref(a: MatRef<'_>, b: MatRef<'_>) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_ref_into(a, b, &mut c, Backend::global());
    c
}

/// y = A · x.
pub fn matvec(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols(), x.len());
    (0..a.rows()).map(|i| super::dot(a.row(i), x)).collect()
}

/// y = A^T · x.
pub fn matvec_t(a: &Mat, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        super::axpy(&mut y, x[i], a.row(i));
    }
    y
}

/// y = A^T · x over a borrowed view — same accumulation as [`matvec_t`].
pub fn matvec_t_ref(a: MatRef<'_>, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows(), x.len());
    let mut y = vec![0.0; a.cols()];
    for i in 0..a.rows() {
        super::axpy(&mut y, x[i], a.row(i));
    }
    y
}

/// C = A^T · A (n×n symmetric from m×n A), honouring the backend switch.
pub fn syrk(a: &Mat, backend: Backend) -> Mat {
    let (m, n) = (a.rows(), a.cols());
    let mut c = Mat::zeros(n, n);
    match backend {
        Backend::Naive => {
            for i in 0..n {
                for j in i..n {
                    let mut s = 0.0;
                    for k in 0..m {
                        s += a[(k, i)] * a[(k, j)];
                    }
                    c[(i, j)] = s;
                    c[(j, i)] = s;
                }
            }
        }
        Backend::Blocked => {
            for k in 0..m {
                let row = a.row(k);
                for i in 0..n {
                    let aki = row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    let crow = c.row_mut(i);
                    for j in i..n {
                        crow[j] += aki * row[j];
                    }
                }
            }
            // mirror the upper triangle
            for i in 0..n {
                for j in i + 1..n {
                    c[(j, i)] = c[(i, j)];
                }
            }
        }
        Backend::Simd => {
            for k in 0..m {
                let row = a.row(k);
                for i in 0..n {
                    let aki = row[i];
                    if aki == 0.0 {
                        continue;
                    }
                    super::simd::axpy(&mut c.row_mut(i)[i..], aki, &row[i..]);
                }
            }
            for i in 0..n {
                for j in i + 1..n {
                    c[(j, i)] = c[(i, j)];
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_mat(r: usize, c: usize, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(r, c);
        rng.fill_normal(m.data_mut());
        m
    }

    fn gemm_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut s = 0.0;
                for k in 0..a.cols() {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn backends_agree_with_reference() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 13, 9), (70, 65, 67), (128, 64, 130)] {
            let a = random_mat(m, k, &mut rng);
            let b = random_mat(k, n, &mut rng);
            let want = gemm_naive(&a, &b);
            for backend in [Backend::Naive, Backend::Blocked, Backend::Simd] {
                let mut c = Mat::zeros(m, n);
                gemm_into(&a, &b, &mut c, backend);
                assert!(c.max_abs_diff(&want) < 1e-9, "{backend:?} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn gemm_tn_matches_explicit_transpose() {
        // explicit-backend entry point: never flips the process global
        // (setting it to Simd mid-run would race concurrent bitwise
        // dispatch tests when Simd is sample-divergent from Blocked)
        let mut rng = Rng::new(2);
        for backend in [Backend::Naive, Backend::Blocked, Backend::Simd] {
            let a = random_mat(23, 7, &mut rng);
            let b = random_mat(23, 11, &mut rng);
            let want = gemm_naive(&a.transpose(), &b);
            let got = gemm_tn_with(&a, &b, backend);
            assert!(got.max_abs_diff(&want) < 1e-9, "{backend:?}");
        }
    }

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(matvec_t(&a, &[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn syrk_backends_agree() {
        let mut rng = Rng::new(3);
        let a = random_mat(31, 12, &mut rng);
        let want = gemm_naive(&a.transpose(), &a);
        for backend in [Backend::Naive, Backend::Blocked, Backend::Simd] {
            let got = syrk(&a, backend);
            assert!(got.max_abs_diff(&want) < 1e-9, "{backend:?}");
            // symmetric
            assert!(got.max_abs_diff(&got.transpose()) < 1e-12);
        }
    }

    #[test]
    fn global_backend_switch() {
        // only the sample-identical scalar pair here: storing Simd in
        // the global mid-suite would change concurrent tests' dispatch
        let prev = Backend::global();
        Backend::set_global(Backend::Naive);
        assert_eq!(Backend::global(), Backend::Naive);
        Backend::set_global(Backend::Blocked);
        assert_eq!(Backend::global(), Backend::Blocked);
        // restore the env-selected backend so a forced-SIMD test run
        // (SMURFF_KERNEL_ISA=simd) keeps exercising SIMD dispatch in the
        // tests scheduled after this one
        Backend::set_global(prev);
    }

    #[test]
    fn backend_parse_and_masks() {
        assert_eq!(Backend::parse("scalar"), Ok(Backend::Blocked));
        assert_eq!(Backend::parse("Blocked"), Ok(Backend::Blocked));
        assert_eq!(Backend::parse("naive"), Ok(Backend::Naive));
        assert_eq!(Backend::parse("simd"), Ok(Backend::Simd));
        assert!(Backend::parse("avx512").is_err());
        // auto resolves to whatever this CPU supports
        let auto = Backend::parse("auto").unwrap();
        assert_eq!(auto, Backend::detect());
        if super::super::simd::available() {
            assert_eq!(Backend::detect(), Backend::Simd);
            assert_eq!(Backend::Simd.sanitized(), Backend::Simd);
            assert_eq!(Backend::Simd.effective(), Backend::Simd);
            assert_ne!(Backend::Simd.isa_label(), "scalar");
        } else {
            assert_eq!(Backend::detect(), Backend::Blocked);
            assert_eq!(Backend::Simd.sanitized(), Backend::Blocked);
            assert_eq!(Backend::Simd.effective(), Backend::Blocked);
            assert_eq!(Backend::Simd.isa_label(), "scalar");
        }
        assert_eq!(Backend::Blocked.isa_label(), "scalar");
        assert_eq!(Backend::Naive.effective(), Backend::Naive);
    }

    #[test]
    #[should_panic]
    fn gemm_checks_shapes() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        gemm(&a, &b);
    }
}
