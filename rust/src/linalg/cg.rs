//! Conjugate-gradient solver for the Macau link-matrix system
//! `(FᵀF + λ I) β_col = rhs` (Simm et al. 2017 solve it with blocked CG
//! so the side-information matrix F never needs to be densified or
//! factorized).  The operator is supplied as a closure so sparse and
//! dense F share the implementation.

/// Solve `A x = b` for SPD `A` given as `apply(v) -> A·v`.
/// Returns (x, iterations). Converges when ‖r‖ ≤ tol·‖b‖.
pub fn cg_solve<F: Fn(&[f64]) -> Vec<f64>>(
    apply: F,
    b: &[f64],
    tol: f64,
    max_iter: usize,
) -> (Vec<f64>, usize) {
    let n = b.len();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let b_norm2: f64 = super::dot(b, b);
    if b_norm2 == 0.0 {
        return (x, 0);
    }
    let tol2 = tol * tol * b_norm2;
    let mut r2 = super::dot(&r, &r);
    for it in 0..max_iter {
        if r2 <= tol2 {
            return (x, it);
        }
        let ap = apply(&p);
        let pap = super::dot(&p, &ap);
        if pap <= 0.0 {
            // operator not SPD within round-off; bail with best effort
            return (x, it);
        }
        let alpha = r2 / pap;
        super::axpy(&mut x, alpha, &p);
        super::axpy(&mut r, -alpha, &ap);
        let r2_new = super::dot(&r, &r);
        let beta = r2_new / r2;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        r2 = r2_new;
    }
    (x, max_iter)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matvec, syrk, Backend, Mat};
    use crate::rng::Rng;

    #[test]
    fn solves_identity() {
        let b = vec![1.0, -2.0, 3.0];
        let (x, it) = cg_solve(|v| v.to_vec(), &b, 1e-12, 10);
        assert!(it <= 2);
        for i in 0..3 {
            assert!((x[i] - b[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn solves_random_spd() {
        let mut rng = Rng::new(4);
        let n = 20;
        let mut g = Mat::zeros(n + 5, n);
        rng.fill_normal(g.data_mut());
        let mut a = syrk(&g, Backend::Blocked);
        for i in 0..n {
            a[(i, i)] += 1.0;
        }
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let (x, it) = cg_solve(|v| matvec(&a, v), &b, 1e-10, 200);
        assert!(it < 200, "did not converge");
        let ax = matvec(&a, &x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-6, "resid at {i}");
        }
    }

    #[test]
    fn zero_rhs_short_circuits() {
        let (x, it) = cg_solve(|v| v.to_vec(), &[0.0; 5], 1e-10, 100);
        assert_eq!(it, 0);
        assert!(x.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn respects_max_iter() {
        let mut rng = Rng::new(5);
        let n = 30;
        let mut g = Mat::zeros(n, n);
        rng.fill_normal(g.data_mut());
        let mut a = syrk(&g, Backend::Blocked);
        for i in 0..n {
            a[(i, i)] += 0.01; // ill-conditioned
        }
        let b = vec![1.0; n];
        let (_, it) = cg_solve(|v| matvec(&a, v), &b, 1e-14, 3);
        assert_eq!(it, 3);
    }
}
