//! Cholesky factorization + triangular solves — the O(K³) core of every
//! row update (`Λ_u = L Lᵀ`, sample `u = Λ⁻¹b + L⁻ᵀ ε`).

use super::Mat;

/// In-place lower Cholesky of an SPD matrix.  Returns Err on a
/// non-positive pivot (matrix not SPD within round-off).
///
/// Always scalar, on every [`super::Backend`]: the K×K factorization is
/// a tiny fraction of the row-update flops, and keeping the pivot
/// recurrence bit-stable means the SIMD backend's only divergence
/// sources are the documented reduction kernels.
pub fn chol_inplace(a: &mut Mat) -> Result<(), &'static str> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    for j in 0..n {
        // d = a[j][j] - sum_{k<j} L[j][k]^2
        let mut d = a[(j, j)];
        for k in 0..j {
            let l = a[(j, k)];
            d -= l * l;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err("matrix is not positive definite");
        }
        let d = d.sqrt();
        a[(j, j)] = d;
        let inv = 1.0 / d;
        for i in j + 1..n {
            let mut s = a[(i, j)];
            // dot of the already-computed parts of rows i and j
            for k in 0..j {
                s -= a[(i, k)] * a[(j, k)];
            }
            a[(i, j)] = s * inv;
        }
        // zero the upper triangle as we go so the result is a clean L
        for i in 0..j {
            a[(i, j)] = 0.0;
        }
    }
    Ok(())
}

/// Owned Cholesky factor with solve helpers.
pub struct Chol {
    l: Mat,
}

impl Chol {
    pub fn new(mut a: Mat) -> Result<Chol, &'static str> {
        chol_inplace(&mut a)?;
        Ok(Chol { l: a })
    }

    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve (L Lᵀ) x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let y = tri_solve_lower(&self.l, b);
        tri_solve_upper_t(&self.l, &y)
    }

    /// Solve Lᵀ x = b (used for the `L⁻ᵀ ε` sampling step).
    pub fn solve_lt(&self, b: &[f64]) -> Vec<f64> {
        tri_solve_upper_t(&self.l, b)
    }

    /// log det(A) = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

/// Forward substitution: solve L y = b for lower-triangular L.
pub fn tri_solve_lower(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; l.rows()];
    tri_solve_lower_into(l, b, &mut y);
    y
}

/// Backward substitution: solve Lᵀ x = b for lower-triangular L.
pub fn tri_solve_upper_t(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut x = vec![0.0; l.rows()];
    tri_solve_upper_t_into(l, b, &mut x);
    x
}

/// One-shot SPD solve: A x = b via Cholesky (A consumed).
pub fn chol_solve(a: Mat, b: &[f64]) -> Result<Vec<f64>, &'static str> {
    Ok(Chol::new(a)?.solve(b))
}

/// Allocation-free forward substitution into `y` (§Perf hot path).
/// Dispatches on the global [`super::Backend`]; the sweep passes its
/// per-session snapshot by picking the twin directly.
pub fn tri_solve_lower_into(l: &Mat, b: &[f64], y: &mut [f64]) {
    if super::simd_enabled() {
        super::simd::tri_solve_lower_into(l, b, y)
    } else {
        tri_solve_lower_into_scalar(l, b, y)
    }
}

/// Scalar twin of [`tri_solve_lower_into`] (the seed arithmetic).
pub fn tri_solve_lower_into_scalar(l: &Mat, b: &[f64], y: &mut [f64]) {
    let n = l.rows();
    debug_assert!(b.len() == n && y.len() == n);
    for i in 0..n {
        let row = l.row(i);
        let s = super::dot_scalar(&row[..i], &y[..i]);
        y[i] = (b[i] - s) / row[i];
    }
}

/// Allocation-free backward substitution (solve Lᵀ x = b) into `x`,
/// dispatching like [`tri_solve_lower_into`].
pub fn tri_solve_upper_t_into(l: &Mat, b: &[f64], x: &mut [f64]) {
    if super::simd_enabled() {
        super::simd::tri_solve_upper_t_into(l, b, x)
    } else {
        tri_solve_upper_t_into_scalar(l, b, x)
    }
}

/// Scalar twin of [`tri_solve_upper_t_into`] (the seed arithmetic:
/// strided column walk, one low-to-high residual pass per output).
pub fn tri_solve_upper_t_into_scalar(l: &Mat, b: &[f64], x: &mut [f64]) {
    let n = l.rows();
    debug_assert!(b.len() == n && x.len() == n);
    for i in (0..n).rev() {
        let mut s = b[i];
        for j in i + 1..n {
            s -= l[(j, i)] * x[j];
        }
        x[i] = s / l[(i, i)];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, syrk, Backend};
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Mat {
        let mut a = Mat::zeros(n + 2, n);
        rng.fill_normal(a.data_mut());
        let mut s = syrk(&a, Backend::Blocked);
        for i in 0..n {
            s[(i, i)] += n as f64;
        }
        s
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 16, 33] {
            let a = random_spd(n, &mut rng);
            let c = Chol::new(a.clone()).unwrap();
            let rec = gemm(c.l(), &c.l().transpose());
            assert!(rec.max_abs_diff(&a) < 1e-8, "n={n}");
            // strictly lower triangular above the diagonal
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(c.l()[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::new(2);
        let n = 12;
        let a = random_spd(n, &mut rng);
        let mut b = vec![0.0; n];
        rng.fill_normal(&mut b);
        let x = chol_solve(a.clone(), &b).unwrap();
        // check A x = b
        let ax = crate::linalg::matvec(&a, &x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn triangular_solves_invert() {
        let mut rng = Rng::new(3);
        let a = random_spd(9, &mut rng);
        let c = Chol::new(a).unwrap();
        let mut b = vec![0.0; 9];
        rng.fill_normal(&mut b);
        let y = tri_solve_lower(c.l(), &b);
        let ly = crate::linalg::matvec(c.l(), &y);
        for i in 0..9 {
            assert!((ly[i] - b[i]).abs() < 1e-9);
        }
        let x = tri_solve_upper_t(c.l(), &b);
        let ltx = crate::linalg::matvec(&c.l().transpose(), &x);
        for i in 0..9 {
            assert!((ltx[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let mut rng = Rng::new(6);
        let a = random_spd(11, &mut rng);
        let c = Chol::new(a).unwrap();
        let mut b = vec![0.0; 11];
        rng.fill_normal(&mut b);
        let mut y = vec![0.0; 11];
        tri_solve_lower_into(c.l(), &b, &mut y);
        assert_eq!(y, tri_solve_lower(c.l(), &b));
        let mut x = vec![0.0; 11];
        tri_solve_upper_t_into(c.l(), &b, &mut x);
        assert_eq!(x, tri_solve_upper_t(c.l(), &b));
    }

    #[test]
    fn log_det_matches_known() {
        // diag(4, 9) -> log det = ln 36
        let a = Mat::from_vec(2, 2, vec![4.0, 0.0, 0.0, 9.0]);
        let c = Chol::new(a).unwrap();
        assert!((c.log_det() - 36f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_non_spd() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(Chol::new(a).is_err());
        let z = Mat::zeros(2, 2);
        assert!(Chol::new(z).is_err());
    }
}
