//! Explicit `std::arch` vector kernels for the Gibbs-sweep hot path —
//! the "hand-tuned beats generic BLAS" half of the paper's Figure-5
//! argument that [`super::Backend::Blocked`] alone (blocking, scalar
//! arithmetic) does not reproduce.
//!
//! Layout mirrors the scalar kernels one-to-one: every public function
//! here has a `*_scalar` twin in `linalg`/`linalg::chol`, and the
//! dispatching wrappers in those modules pick between the two based on
//! [`super::Backend::global()`].  On x86_64 the vector arms need
//! AVX2+FMA (checked once at runtime via `is_x86_feature_detected!`,
//! cached in a [`OnceLock`]); on aarch64 NEON is architecturally
//! baseline.  On any other target — or when the features are missing —
//! every wrapper silently runs its scalar twin, so calling into this
//! module is always safe and always correct, just not always vectorized.
//!
//! ## Tolerance contract
//!
//! FMA contraction and vector-lane reassociation change the summation
//! order, so SIMD results are **not** bit-identical to the scalar
//! kernels.  The documented contract (property-tested in
//! `tests/simd_props.rs` and below) is a relative error bound of
//! `SIMD_REL_TOL_PER_ELEM * n` against the scalar twin, where `n` is
//! the reduction length — the standard `O(n·eps)` backward-error bound,
//! with a constant small enough that both orderings sit within a few
//! hundred ulps of the exact sum for every shape the sweep produces.
//! Within the SIMD family the PR 4 structural contracts still hold
//! bitwise: [`gram_rhs_tile`] replays [`gram_rhs_rank4`]'s per-element
//! order (both call the same inner helpers), and [`dots_into`] runs
//! [`dot`]'s exact reduction per panel row.
//!
//! ## Strict mode
//!
//! [`set_strict`]`(true)` pins every dispatcher to the scalar path
//! regardless of the selected backend — the reproducibility escape
//! hatch for the bit-identity property tests and for distributed runs
//! that must hash-agree with scalar baselines recorded elsewhere.

use super::{Mat, MatRef};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Per-element relative tolerance of the SIMD-vs-scalar contract; the
/// total bound for a length-`n` reduction is `SIMD_REL_TOL_PER_ELEM * n`
/// (see module docs).  `4·eps` absorbs the worst observed reassociation
/// drift with an order of magnitude to spare.
pub const SIMD_REL_TOL_PER_ELEM: f64 = 4.0 * f64::EPSILON;

/// CPU vector features relevant to the f64 kernels, detected once.
#[derive(Debug, Clone, Copy)]
pub struct CpuFeatures {
    pub avx2: bool,
    pub fma: bool,
    pub neon: bool,
}

impl CpuFeatures {
    /// True when a vector arm exists for this CPU.
    pub fn usable(&self) -> bool {
        (self.avx2 && self.fma) || self.neon
    }
}

static CPU_FEATURES: OnceLock<CpuFeatures> = OnceLock::new();

/// Runtime CPU-feature snapshot (detected on first call, then cached).
pub fn cpu_features() -> &'static CpuFeatures {
    CPU_FEATURES.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            CpuFeatures {
                avx2: std::arch::is_x86_feature_detected!("avx2"),
                fma: std::arch::is_x86_feature_detected!("fma"),
                neon: false,
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON (ASIMD) is architecturally mandatory on AArch64
            CpuFeatures { avx2: false, fma: false, neon: true }
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            CpuFeatures { avx2: false, fma: false, neon: false }
        }
    })
}

/// True when the SIMD kernels would actually run vector code here.
pub fn available() -> bool {
    cpu_features().usable()
}

/// Human-readable name of the vector ISA the SIMD backend uses on this
/// CPU ("avx2+fma", "neon"), or "scalar" when none is available.
pub fn isa_name() -> &'static str {
    let f = cpu_features();
    if f.avx2 && f.fma {
        "avx2+fma"
    } else if f.neon {
        "neon"
    } else {
        "scalar"
    }
}

static STRICT: AtomicBool = AtomicBool::new(false);

/// Pin every backend dispatcher to the scalar path (see module docs).
pub fn set_strict(on: bool) {
    STRICT.store(on, Ordering::Relaxed);
}

/// Is strict (scalar-pinned) mode on?
pub fn strict() -> bool {
    STRICT.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Safe wrappers.  Each checks `available()` and falls back to the
// scalar twin, so the `unsafe` target-feature arms are provably only
// reached when the features were detected.
// ---------------------------------------------------------------------

/// Vector dot product (8-wide FMA accumulation on AVX2, 4-wide on NEON,
/// serial tail).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::dot(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::dot(a, b) };
    }
    super::dot_scalar(a, b)
}

/// Three-way Hadamard dot `Σ_i a_i·b_i·c_i` — the 3-mode tensor
/// [`crate::model::hadamard_dot`] reduction.
#[inline]
pub fn dot3(a: &[f64], b: &[f64], c: &[f64]) -> f64 {
    debug_assert!(a.len() == b.len() && a.len() == c.len());
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::dot3(a, b, c) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::dot3(a, b, c) };
    }
    let mut s = [0.0f64; 4];
    let chunks = a.len() / 4;
    for ch in 0..chunks {
        let i = ch * 4;
        for l in 0..4 {
            s[l] += a[i + l] * b[i + l] * c[i + l];
        }
    }
    let mut rest = 0.0;
    for i in chunks * 4..a.len() {
        rest += a[i] * b[i] * c[i];
    }
    s[0] + s[1] + s[2] + s[3] + rest
}

/// y += s·x with FMA lanes.
#[inline]
pub fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::axpy(y, s, x) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::axpy(y, s, x) };
    }
    super::axpy_scalar(y, s, x)
}

/// c += a0·x0 + a1·x1 — the 2-way-unrolled gemm microkernel inner loop.
#[inline]
pub fn fma2_into(c: &mut [f64], a0: f64, x0: &[f64], a1: f64, x1: &[f64]) {
    debug_assert!(c.len() == x0.len() && c.len() == x1.len());
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::fma2_into(c, a0, x0, a1, x1) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::fma2_into(c, a0, x0, a1, x1) };
    }
    for i in 0..c.len() {
        c[i] += a0 * x0[i] + a1 * x1[i];
    }
}

/// Batched panel dot: `out[j] += dot(x, a.row(j))` — runs [`dot`]'s
/// exact reduction per row, so every output is bit-identical to a
/// standalone [`dot`] call (the serving-path contract, ISA-uniform).
pub fn dots_into(x: &[f64], a: MatRef<'_>, out: &mut [f64]) {
    debug_assert_eq!(a.cols(), x.len());
    debug_assert_eq!(a.rows(), out.len());
    for (j, o) in out.iter_mut().enumerate() {
        *o += dot(x, a.row(j));
    }
}

/// Fused Gram + RHS over a gathered batch — vector sibling of
/// [`super::gram_rhs_rank4_scalar`]; same rank-4 grouping, FMA lanes.
pub fn gram_rhs_rank4(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    let k = rhs.len();
    debug_assert_eq!(a.rows(), k);
    debug_assert_eq!(xs.len(), vals.len() * k);
    let nnz = vals.len();
    let mut t = 0;
    while t + 4 <= nnz {
        let x4 = [
            &xs[t * k..(t + 1) * k],
            &xs[(t + 1) * k..(t + 2) * k],
            &xs[(t + 2) * k..(t + 3) * k],
            &xs[(t + 3) * k..(t + 4) * k],
        ];
        for i in 0..k {
            gram_update4(&mut a.row_mut(i)[i..], i, x4, alpha);
        }
        rhs_update4(rhs, alpha, x4, [vals[t], vals[t + 1], vals[t + 2], vals[t + 3]]);
        t += 4;
    }
    while t < nnz {
        let x = &xs[t * k..(t + 1) * k];
        for i in 0..k {
            axpy(&mut a.row_mut(i)[i..], alpha * x[i], &x[i..]);
        }
        axpy(rhs, alpha * vals[t], x);
        t += 1;
    }
}

/// Tiled sibling of [`gram_rhs_rank4`] (i-outer / group-middle /
/// j-inner).  Calls the *same* inner helpers in the same per-element
/// order, so tile-by-tile accumulation with a multiple-of-4 tile stays
/// bit-identical to one [`gram_rhs_rank4`] call — the PR 4 structural
/// contract, preserved inside the SIMD family.
pub fn gram_rhs_tile(a: &mut Mat, rhs: &mut [f64], alpha: f64, xs: &[f64], vals: &[f64]) {
    let k = rhs.len();
    debug_assert_eq!(a.rows(), k);
    debug_assert_eq!(xs.len(), vals.len() * k);
    let nnz = vals.len();
    let groups = nnz / 4;
    for i in 0..k {
        let row = a.row_mut(i);
        for g in 0..groups {
            let t = g * 4;
            let x4 = [
                &xs[t * k..(t + 1) * k],
                &xs[(t + 1) * k..(t + 2) * k],
                &xs[(t + 2) * k..(t + 3) * k],
                &xs[(t + 3) * k..(t + 4) * k],
            ];
            gram_update4(&mut row[i..], i, x4, alpha);
        }
        for t in groups * 4..nnz {
            let x = &xs[t * k..(t + 1) * k];
            axpy(&mut row[i..], alpha * x[i], &x[i..]);
        }
    }
    for g in 0..groups {
        let t = g * 4;
        let x4 = [
            &xs[t * k..(t + 1) * k],
            &xs[(t + 1) * k..(t + 2) * k],
            &xs[(t + 2) * k..(t + 3) * k],
            &xs[(t + 3) * k..(t + 4) * k],
        ];
        rhs_update4(rhs, alpha, x4, [vals[t], vals[t + 1], vals[t + 2], vals[t + 3]]);
    }
    for t in groups * 4..nnz {
        axpy(rhs, alpha * vals[t], &xs[t * k..(t + 1) * k]);
    }
}

/// row[j] += Σ_l (alpha·x4[l][off])·x4[l][off+j] — the shared 4-row
/// Gram inner of [`gram_rhs_rank4`] and [`gram_rhs_tile`].  `row` is the
/// upper-triangle suffix starting at column `off`; `x4[l][off..]` are
/// the matching source suffixes.
#[inline]
fn gram_update4(row: &mut [f64], off: usize, x4: [&[f64]; 4], alpha: f64) {
    let a4 = [
        alpha * x4[0][off],
        alpha * x4[1][off],
        alpha * x4[2][off],
        alpha * x4[3][off],
    ];
    let s4 = [&x4[0][off..], &x4[1][off..], &x4[2][off..], &x4[3][off..]];
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::fma4_into(row, a4, s4) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::fma4_into(row, a4, s4) };
    }
    for (j, rj) in row.iter_mut().enumerate() {
        *rj += a4[0] * s4[0][j] + a4[1] * s4[1][j] + a4[2] * s4[2][j] + a4[3] * s4[3][j];
    }
}

/// rhs[j] += alpha·Σ_l v4[l]·x4[l][j] — the shared 4-row RHS inner.
#[inline]
fn rhs_update4(rhs: &mut [f64], alpha: f64, x4: [&[f64]; 4], v4: [f64; 4]) {
    #[cfg(target_arch = "x86_64")]
    if cpu_features().usable() {
        return unsafe { x86::rhs4_into(rhs, alpha, x4, v4) };
    }
    #[cfg(target_arch = "aarch64")]
    if cpu_features().usable() {
        return unsafe { arm::rhs4_into(rhs, alpha, x4, v4) };
    }
    for (j, rj) in rhs.iter_mut().enumerate() {
        *rj += alpha * (v4[0] * x4[0][j] + v4[1] * x4[1][j] + v4[2] * x4[2][j] + v4[3] * x4[3][j]);
    }
}

/// Forward substitution with the vector [`dot`] on each row prefix.
pub fn tri_solve_lower_into(l: &Mat, b: &[f64], y: &mut [f64]) {
    let n = l.rows();
    debug_assert!(b.len() == n && y.len() == n);
    for i in 0..n {
        let row = l.row(i);
        let s = dot(&row[..i], &y[..i]);
        y[i] = (b[i] - s) / row[i];
    }
}

/// Backward substitution (solve Lᵀx = b) in outer-product form: after
/// fixing `x[i]`, subtract `x[i]·L[i, ..i]` from the running residual —
/// a contiguous [`axpy`] over row `i` of L instead of the scalar twin's
/// strided column walk.  Different summation order than the scalar
/// kernel (each residual element receives contributions high-to-low
/// instead of in one low-to-high pass), covered by the tolerance
/// contract.
pub fn tri_solve_upper_t_into(l: &Mat, b: &[f64], x: &mut [f64]) {
    let n = l.rows();
    debug_assert!(b.len() == n && x.len() == n);
    x.copy_from_slice(b);
    for i in (0..n).rev() {
        let xi = x[i] / l[(i, i)];
        x[i] = xi;
        let (head, _) = x.split_at_mut(i);
        axpy(head, -xi, &l.row(i)[..i]);
    }
}

// ---------------------------------------------------------------------
// AVX2+FMA arms.  All unsafe fns here require the features checked by
// the safe wrappers above; loads/stores are unaligned (`loadu`), so the
// only precondition is slice-length agreement, which the wrappers
// debug-assert and the loop bounds enforce.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    #[inline]
    unsafe fn hsum(v: __m256d) -> f64 {
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), v);
        (lanes[0] + lanes[1]) + (lanes[2] + lanes[3])
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut i = 0;
        while i + 8 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i)), acc0);
            acc1 = _mm256_fmadd_pd(
                _mm256_loadu_pd(ap.add(i + 4)),
                _mm256_loadu_pd(bp.add(i + 4)),
                acc1,
            );
            i += 8;
        }
        if i + 4 <= n {
            acc0 = _mm256_fmadd_pd(_mm256_loadu_pd(ap.add(i)), _mm256_loadu_pd(bp.add(i)), acc0);
            i += 4;
        }
        let mut s = hsum(_mm256_add_pd(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot3(a: &[f64], b: &[f64], c: &[f64]) -> f64 {
        let n = a.len();
        let mut acc = _mm256_setzero_pd();
        let mut i = 0;
        while i + 4 <= n {
            let ab = _mm256_mul_pd(
                _mm256_loadu_pd(a.as_ptr().add(i)),
                _mm256_loadu_pd(b.as_ptr().add(i)),
            );
            acc = _mm256_fmadd_pd(ab, _mm256_loadu_pd(c.as_ptr().add(i)), acc);
            i += 4;
        }
        let mut s = hsum(acc);
        while i < n {
            s += a[i] * b[i] * c[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
        let n = y.len();
        let vs = _mm256_set1_pd(s);
        let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
        let mut i = 0;
        while i + 4 <= n {
            let r = _mm256_fmadd_pd(vs, _mm256_loadu_pd(xp.add(i)), _mm256_loadu_pd(yp.add(i)));
            _mm256_storeu_pd(yp.add(i), r);
            i += 4;
        }
        while i < n {
            y[i] = s.mul_add(x[i], y[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma2_into(c: &mut [f64], a0: f64, x0: &[f64], a1: f64, x1: &[f64]) {
        let n = c.len();
        let (va0, va1) = (_mm256_set1_pd(a0), _mm256_set1_pd(a1));
        let cp = c.as_mut_ptr();
        let mut i = 0;
        while i + 4 <= n {
            let mut r = _mm256_loadu_pd(cp.add(i));
            r = _mm256_fmadd_pd(va0, _mm256_loadu_pd(x0.as_ptr().add(i)), r);
            r = _mm256_fmadd_pd(va1, _mm256_loadu_pd(x1.as_ptr().add(i)), r);
            _mm256_storeu_pd(cp.add(i), r);
            i += 4;
        }
        while i < n {
            c[i] = a1.mul_add(x1[i], a0.mul_add(x0[i], c[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn fma4_into(row: &mut [f64], a4: [f64; 4], s4: [&[f64]; 4]) {
        let n = row.len();
        let va = [
            _mm256_set1_pd(a4[0]),
            _mm256_set1_pd(a4[1]),
            _mm256_set1_pd(a4[2]),
            _mm256_set1_pd(a4[3]),
        ];
        let rp = row.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let mut r = _mm256_loadu_pd(rp.add(j));
            r = _mm256_fmadd_pd(va[0], _mm256_loadu_pd(s4[0].as_ptr().add(j)), r);
            r = _mm256_fmadd_pd(va[1], _mm256_loadu_pd(s4[1].as_ptr().add(j)), r);
            r = _mm256_fmadd_pd(va[2], _mm256_loadu_pd(s4[2].as_ptr().add(j)), r);
            r = _mm256_fmadd_pd(va[3], _mm256_loadu_pd(s4[3].as_ptr().add(j)), r);
            _mm256_storeu_pd(rp.add(j), r);
            j += 4;
        }
        while j < n {
            let mut r = row[j];
            r = a4[0].mul_add(s4[0][j], r);
            r = a4[1].mul_add(s4[1][j], r);
            r = a4[2].mul_add(s4[2][j], r);
            r = a4[3].mul_add(s4[3][j], r);
            row[j] = r;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn rhs4_into(rhs: &mut [f64], alpha: f64, x4: [&[f64]; 4], v4: [f64; 4]) {
        let n = rhs.len();
        let valpha = _mm256_set1_pd(alpha);
        let vv = [
            _mm256_set1_pd(v4[0]),
            _mm256_set1_pd(v4[1]),
            _mm256_set1_pd(v4[2]),
            _mm256_set1_pd(v4[3]),
        ];
        let rp = rhs.as_mut_ptr();
        let mut j = 0;
        while j + 4 <= n {
            let mut t = _mm256_mul_pd(vv[0], _mm256_loadu_pd(x4[0].as_ptr().add(j)));
            t = _mm256_fmadd_pd(vv[1], _mm256_loadu_pd(x4[1].as_ptr().add(j)), t);
            t = _mm256_fmadd_pd(vv[2], _mm256_loadu_pd(x4[2].as_ptr().add(j)), t);
            t = _mm256_fmadd_pd(vv[3], _mm256_loadu_pd(x4[3].as_ptr().add(j)), t);
            let r = _mm256_fmadd_pd(valpha, t, _mm256_loadu_pd(rp.add(j)));
            _mm256_storeu_pd(rp.add(j), r);
            j += 4;
        }
        while j < n {
            let mut t = v4[0] * x4[0][j];
            t = v4[1].mul_add(x4[1][j], t);
            t = v4[2].mul_add(x4[2][j], t);
            t = v4[3].mul_add(x4[3][j], t);
            rhs[j] = alpha.mul_add(t, rhs[j]);
            j += 1;
        }
    }
}

// ---------------------------------------------------------------------
// NEON arms (2-lane f64).  NEON is baseline on aarch64, so the feature
// gate is formal; the wrappers still route through `cpu_features()`.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f64], b: &[f64]) -> f64 {
        let n = a.len();
        let (ap, bp) = (a.as_ptr(), b.as_ptr());
        let mut acc0 = vdupq_n_f64(0.0);
        let mut acc1 = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 4 <= n {
            acc0 = vfmaq_f64(acc0, vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i)));
            acc1 = vfmaq_f64(acc1, vld1q_f64(ap.add(i + 2)), vld1q_f64(bp.add(i + 2)));
            i += 4;
        }
        if i + 2 <= n {
            acc0 = vfmaq_f64(acc0, vld1q_f64(ap.add(i)), vld1q_f64(bp.add(i)));
            i += 2;
        }
        let mut s = vaddvq_f64(vaddq_f64(acc0, acc1));
        while i < n {
            s += a[i] * b[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot3(a: &[f64], b: &[f64], c: &[f64]) -> f64 {
        let n = a.len();
        let mut acc = vdupq_n_f64(0.0);
        let mut i = 0;
        while i + 2 <= n {
            let ab = vmulq_f64(vld1q_f64(a.as_ptr().add(i)), vld1q_f64(b.as_ptr().add(i)));
            acc = vfmaq_f64(acc, ab, vld1q_f64(c.as_ptr().add(i)));
            i += 2;
        }
        let mut s = vaddvq_f64(acc);
        while i < n {
            s += a[i] * b[i] * c[i];
            i += 1;
        }
        s
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn axpy(y: &mut [f64], s: f64, x: &[f64]) {
        let n = y.len();
        let vs = vdupq_n_f64(s);
        let (yp, xp) = (y.as_mut_ptr(), x.as_ptr());
        let mut i = 0;
        while i + 2 <= n {
            let r = vfmaq_f64(vld1q_f64(yp.add(i)), vs, vld1q_f64(xp.add(i)));
            vst1q_f64(yp.add(i), r);
            i += 2;
        }
        while i < n {
            y[i] = s.mul_add(x[i], y[i]);
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma2_into(c: &mut [f64], a0: f64, x0: &[f64], a1: f64, x1: &[f64]) {
        let n = c.len();
        let (va0, va1) = (vdupq_n_f64(a0), vdupq_n_f64(a1));
        let cp = c.as_mut_ptr();
        let mut i = 0;
        while i + 2 <= n {
            let mut r = vld1q_f64(cp.add(i));
            r = vfmaq_f64(r, va0, vld1q_f64(x0.as_ptr().add(i)));
            r = vfmaq_f64(r, va1, vld1q_f64(x1.as_ptr().add(i)));
            vst1q_f64(cp.add(i), r);
            i += 2;
        }
        while i < n {
            c[i] = a1.mul_add(x1[i], a0.mul_add(x0[i], c[i]));
            i += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn fma4_into(row: &mut [f64], a4: [f64; 4], s4: [&[f64]; 4]) {
        let n = row.len();
        let va = [
            vdupq_n_f64(a4[0]),
            vdupq_n_f64(a4[1]),
            vdupq_n_f64(a4[2]),
            vdupq_n_f64(a4[3]),
        ];
        let rp = row.as_mut_ptr();
        let mut j = 0;
        while j + 2 <= n {
            let mut r = vld1q_f64(rp.add(j));
            r = vfmaq_f64(r, va[0], vld1q_f64(s4[0].as_ptr().add(j)));
            r = vfmaq_f64(r, va[1], vld1q_f64(s4[1].as_ptr().add(j)));
            r = vfmaq_f64(r, va[2], vld1q_f64(s4[2].as_ptr().add(j)));
            r = vfmaq_f64(r, va[3], vld1q_f64(s4[3].as_ptr().add(j)));
            vst1q_f64(rp.add(j), r);
            j += 2;
        }
        while j < n {
            let mut r = row[j];
            r = a4[0].mul_add(s4[0][j], r);
            r = a4[1].mul_add(s4[1][j], r);
            r = a4[2].mul_add(s4[2][j], r);
            r = a4[3].mul_add(s4[3][j], r);
            row[j] = r;
            j += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn rhs4_into(rhs: &mut [f64], alpha: f64, x4: [&[f64]; 4], v4: [f64; 4]) {
        let n = rhs.len();
        let valpha = vdupq_n_f64(alpha);
        let vv = [
            vdupq_n_f64(v4[0]),
            vdupq_n_f64(v4[1]),
            vdupq_n_f64(v4[2]),
            vdupq_n_f64(v4[3]),
        ];
        let rp = rhs.as_mut_ptr();
        let mut j = 0;
        while j + 2 <= n {
            let mut t = vmulq_f64(vv[0], vld1q_f64(x4[0].as_ptr().add(j)));
            t = vfmaq_f64(t, vv[1], vld1q_f64(x4[1].as_ptr().add(j)));
            t = vfmaq_f64(t, vv[2], vld1q_f64(x4[2].as_ptr().add(j)));
            t = vfmaq_f64(t, vv[3], vld1q_f64(x4[3].as_ptr().add(j)));
            let r = vfmaq_f64(vld1q_f64(rp.add(j)), valpha, t);
            vst1q_f64(rp.add(j), r);
            j += 2;
        }
        while j < n {
            let mut t = v4[0] * x4[0][j];
            t = v4[1].mul_add(x4[1][j], t);
            t = v4[2].mul_add(x4[2][j], t);
            t = v4[3].mul_add(x4[3][j], t);
            rhs[j] = alpha.mul_add(t, rhs[j]);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{
        dot_scalar, gram_rhs_rank4_scalar, mirror_upper_to_lower, tri_solve_lower_into_scalar,
        tri_solve_upper_t_into_scalar,
    };
    use crate::rng::Rng;

    fn rel_close(a: f64, b: f64, n: usize, mag: f64) -> bool {
        let tol = SIMD_REL_TOL_PER_ELEM * (n.max(1) as f64) * mag.max(1.0);
        (a - b).abs() <= tol
    }

    #[test]
    fn detection_is_stable_and_consistent() {
        let f1 = *cpu_features();
        let f2 = *cpu_features();
        assert_eq!(f1.usable(), f2.usable());
        assert_eq!(available(), f1.usable());
        if available() {
            assert_ne!(isa_name(), "scalar");
        } else {
            assert_eq!(isa_name(), "scalar");
        }
    }

    #[test]
    fn dot_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(71);
        // lengths straddle every remainder-lane case: 0, <4, 4, 5..8, odd
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 64, 65, 127] {
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            let got = dot(&a, &b);
            let want = dot_scalar(&a, &b);
            let mag: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            assert!(rel_close(got, want, n, mag), "n={n} got={got} want={want}");
        }
    }

    #[test]
    fn dot3_matches_naive_within_tolerance() {
        let mut rng = Rng::new(72);
        for n in [0usize, 1, 3, 4, 6, 7, 16, 33] {
            let mut a = vec![0.0; n];
            let mut b = vec![0.0; n];
            let mut c = vec![0.0; n];
            rng.fill_normal(&mut a);
            rng.fill_normal(&mut b);
            rng.fill_normal(&mut c);
            let got = dot3(&a, &b, &c);
            let want: f64 = (0..n).map(|i| a[i] * b[i] * c[i]).sum();
            let mag: f64 = (0..n).map(|i| (a[i] * b[i] * c[i]).abs()).sum();
            assert!(rel_close(got, want, n, mag), "n={n}");
        }
    }

    #[test]
    fn axpy_and_fma2_match_scalar() {
        let mut rng = Rng::new(73);
        for n in [0usize, 1, 3, 4, 5, 11, 16, 31] {
            let mut y0 = vec![0.0; n];
            rng.fill_normal(&mut y0);
            let mut x0 = vec![0.0; n];
            let mut x1 = vec![0.0; n];
            rng.fill_normal(&mut x0);
            rng.fill_normal(&mut x1);
            let mut ys = y0.clone();
            crate::linalg::axpy_scalar(&mut ys, 1.3, &x0);
            let mut yv = y0.clone();
            axpy(&mut yv, 1.3, &x0);
            for i in 0..n {
                assert!(rel_close(yv[i], ys[i], 1, ys[i].abs()), "axpy n={n} i={i}");
            }
            let mut cs = y0.clone();
            for i in 0..n {
                cs[i] += 0.7 * x0[i] + -0.2 * x1[i];
            }
            let mut cv = y0.clone();
            fma2_into(&mut cv, 0.7, &x0, -0.2, &x1);
            for i in 0..n {
                assert!(rel_close(cv[i], cs[i], 2, cs[i].abs()), "fma2 n={n} i={i}");
            }
        }
    }

    #[test]
    fn dots_into_is_bitwise_dot_per_row() {
        let mut rng = Rng::new(74);
        for (rows, k) in [(0usize, 4usize), (1, 3), (5, 16), (7, 5), (12, 17)] {
            let mut panel = crate::linalg::Mat::zeros(rows, k);
            rng.fill_normal(panel.data_mut());
            let mut x = vec![0.0; k];
            rng.fill_normal(&mut x);
            let mut out = vec![0.5; rows];
            dots_into(&x, panel.view(), &mut out);
            for j in 0..rows {
                let want = 0.5 + dot(&x, panel.row(j));
                assert_eq!(out[j].to_bits(), want.to_bits(), "rows={rows} k={k} j={j}");
            }
        }
    }

    #[test]
    fn gram_tile_is_bit_identical_to_gram_rank4() {
        // the PR 4 structural contract, restated inside the SIMD family
        let mut rng = Rng::new(75);
        for (k, nnz) in [(3usize, 1usize), (8, 31), (16, 32), (16, 70), (5, 129)] {
            let mut xs = vec![0.0; nnz * k];
            let mut vals = vec![0.0; nnz];
            rng.fill_normal(&mut xs);
            rng.fill_normal(&mut vals);
            let mut a4 = crate::linalg::Mat::eye(k);
            let mut r4 = vec![0.25; k];
            gram_rhs_rank4(&mut a4, &mut r4, 0.9, &xs, &vals);
            let mut at = crate::linalg::Mat::eye(k);
            let mut rt = vec![0.25; k];
            let mut t0 = 0;
            while t0 < nnz {
                let t1 = (t0 + crate::linalg::GRAM_TILE_ROWS).min(nnz);
                gram_rhs_tile(&mut at, &mut rt, 0.9, &xs[t0 * k..t1 * k], &vals[t0..t1]);
                t0 = t1;
            }
            assert_eq!(a4.max_abs_diff(&at), 0.0, "Λ k={k} nnz={nnz}");
            for (x, y) in r4.iter().zip(&rt) {
                assert_eq!(x.to_bits(), y.to_bits(), "rhs k={k} nnz={nnz}");
            }
        }
    }

    #[test]
    fn gram_matches_scalar_within_tolerance() {
        let mut rng = Rng::new(76);
        for (k, nnz) in [(4usize, 1usize), (8, 3), (16, 11), (5, 37), (33, 64)] {
            let mut xs = vec![0.0; nnz * k];
            let mut vals = vec![0.0; nnz];
            rng.fill_normal(&mut xs);
            rng.fill_normal(&mut vals);
            let mut av = crate::linalg::Mat::eye(k);
            let mut rv = vec![0.5; k];
            gram_rhs_rank4(&mut av, &mut rv, 1.7, &xs, &vals);
            mirror_upper_to_lower(&mut av);
            let mut a_s = crate::linalg::Mat::eye(k);
            let mut rs = vec![0.5; k];
            gram_rhs_rank4_scalar(&mut a_s, &mut rs, 1.7, &xs, &vals);
            mirror_upper_to_lower(&mut a_s);
            let tol = SIMD_REL_TOL_PER_ELEM * (nnz as f64) * 16.0;
            assert!(av.max_abs_diff(&a_s) < tol.max(1e-10), "Λ k={k} nnz={nnz}");
            for (x, y) in rv.iter().zip(&rs) {
                assert!((x - y).abs() < tol.max(1e-10), "rhs k={k} nnz={nnz}");
            }
        }
    }

    #[test]
    fn tri_solves_match_scalar_within_tolerance() {
        let mut rng = Rng::new(77);
        for n in [1usize, 2, 3, 5, 9, 16, 31] {
            // well-conditioned lower-triangular factor
            let mut l = crate::linalg::Mat::zeros(n, n);
            for i in 0..n {
                for j in 0..i {
                    l[(i, j)] = 0.3 * ((i + 2 * j) % 5) as f64 / 5.0;
                }
                l[(i, i)] = 1.5 + (i % 3) as f64 * 0.25;
            }
            let mut b = vec![0.0; n];
            rng.fill_normal(&mut b);
            let mut ys = vec![0.0; n];
            tri_solve_lower_into_scalar(&l, &b, &mut ys);
            let mut yv = vec![0.0; n];
            tri_solve_lower_into(&l, &b, &mut yv);
            let mut xs = vec![0.0; n];
            tri_solve_upper_t_into_scalar(&l, &b, &mut xs);
            let mut xv = vec![0.0; n];
            tri_solve_upper_t_into(&l, &b, &mut xv);
            let tol = SIMD_REL_TOL_PER_ELEM * (n as f64) * 64.0;
            for i in 0..n {
                assert!((ys[i] - yv[i]).abs() <= tol.max(1e-12), "lower n={n} i={i}");
                assert!((xs[i] - xv[i]).abs() <= tol.max(1e-12), "upper_t n={n} i={i}");
            }
        }
    }

    // NOTE: no unit test toggles `set_strict` here — flipping the
    // process-global flag races the dispatch-bitwise tests above when
    // the suite runs with SMURFF_KERNEL_ISA=simd.  Strict-mode coverage
    // lives in the dedicated `tests/strict_mode.rs` binary, which owns
    // the flag for its whole process.
}
