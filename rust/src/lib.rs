//! # SMURFF-RS — a high-performance framework for Bayesian Matrix Factorization
//!
//! Rust + JAX + Pallas reproduction of *SMURFF: a High-Performance Framework
//! for Matrix Factorization* (Vander Aa et al., 2019).  See `DESIGN.md` for
//! the full system inventory and experiment index.
//!
//! The crate is organised in layers:
//!
//! * substrates: [`util`], [`rng`], [`linalg`] (BLAS-like kernels on a
//!               three-way `Backend` axis — naive / cache-blocked
//!               scalar / `linalg::simd` AVX2+FMA/NEON vector variants
//!               with one-time runtime CPU-feature detection, scalar
//!               fallback, and a strict mode pinning the bit-exact
//!               seed path — see README §Performance), [`sparse`] (CSR/CSC
//!               matrices *and* the N-mode [`sparse::SparseTensor`]
//!               with one compressed fiber index per mode), [`obs`]
//!               (the process-wide observability registry: atomic
//!               counters/gauges/histograms with p50/p90/p99
//!               estimation, Prometheus text exposition, and span
//!               tracing emitting Chrome trace-event JSON — every
//!               layer below reports through it, and instrumentation
//!               is sample-preserving by construction), [`diag`]
//!               (sampler-health diagnostics: a `ChainMonitor` fed
//!               per-iteration scalar summaries computing split-chain
//!               R̂ / autocorrelation ESS / Geweke burn-in flags, plus
//!               FNV-1a chain-state hashing that the distributed layer
//!               compares across ranks at every sync point — like
//!               [`obs`], strictly read-only over the model)
//! * framework:  [`data`], [`noise`], [`priors`], [`model`], [`session`]
//!               — sessions factorize both matrix views and N-mode
//!               tensor views (CP/PARAFAC) with per-mode priors; the
//!               2-mode tensor path is bit-identical to the matrix path
//! * runtime:    [`coordinator`] (work-stealing parallel Gibbs over an
//!               *operand* abstraction — per observation the MVN
//!               conditional consumes a design row: the opposite side's
//!               latents for matrices, the other modes' Hadamard
//!               product for tensors — executed through a per-sweep
//!               `SweepPlan`: cache-blocked tiled Gram above an nnz
//!               threshold, adaptive-noise SSE fused into the final
//!               mode's sweep, hoisted shared-rhs base, descending-nnz
//!               LPT scheduling and per-lane work arenas, every switch
//!               bit-exactness-preserving — see README §Performance and
//!               `bench sweep`), [`runtime`] (PJRT/XLA AOT engine)
//! * distributed: [`distributed`] — `comm` (message substrate with
//!               allgather/allreduce/sub-communicators, byte + time
//!               accounting, and a deadline/backoff receive path with
//!               at-least-once sends and per-sender duplicate
//!               suppression), [`distributed::fault`] (chaos + failure
//!               detection: the deterministic seedable `FaultPlan`
//!               injecting message delay/drop/duplication/reorder and
//!               rank crashes, the shared heartbeat board and the
//!               K-missed-beats failure detector), `shard`
//!               (nnz-balanced block ownership and data scatter,
//!               including live-rank re-planning after a death),
//!               `session` (`DistributedSession`: any builder
//!               composition across sharded nodes under sync /
//!               bounded-staleness async / posterior-propagation
//!               communication strategies; with fault tolerance armed,
//!               survivors re-shard a dead rank's block and
//!               warm-restart from the in-memory checkpoint ring — see
//!               README §Robustness)
//! * serving:    [`store`] (versioned on-disk posterior model store —
//!               one factor matrix per mode; version-1/2 stores still
//!               load, and `ModelStore::compact()` migrates any of them
//!               into the **packed v3 artifact**: one page-aligned
//!               binary file per view with all samples' factors in
//!               sample-major blocks, mmap'd zero-copy on unix),
//!               [`predict`] (an immutable `Arc<ServingModel>` of
//!               borrowed sample-major factor panels under
//!               `PredictSession`: row-grouped batched pointwise
//!               prediction with a posterior-mean fast path, panel-dot
//!               top-K, per-sample-GEMM dense blocks — every batched
//!               path bit-identical to the scalar path — plus tensor
//!               coordinate serving and out-of-matrix prediction via
//!               Macau side info), [`serve`] (`smurff serve`: a TCP
//!               front-end speaking newline-delimited JSON — a bounded
//!               connection-worker pool (`serve::pool`) caps live
//!               handlers and sheds excess connections, a multi-model
//!               registry (`serve::registry`) hosts several named
//!               stores per process each with its own micro-batching
//!               queue and hot-reload snapshot watcher, a sharded
//!               per-model top-K reply LRU ([`serve::cache`]) replays
//!               exact reply bytes and is generation-guard invalidated
//!               on reload, and overload hardening — load shedding
//!               with structured `overloaded` replies, per-request
//!               deadlines, capped request lines, slow-client write
//!               timeouts and a graceful shutdown drain; plus
//!               [`serve::loadgen`] (`smurff loadgen`: an open-loop
//!               power-law load harness emitting the saturation table))
//! * evaluation: [`baselines`] (PyMC3-like, GraphChi-like, GASPI-like),
//!               [`hwmodel`] (Xeon / Xeon Phi / ARM roofline+cache model),
//!               [`bench`] (the harness regenerating every paper figure)
//!
//! ## Quickstart: train, persist, serve
//!
//! SMURFF's workflow is two-phase: a Gibbs *train session* persists
//! posterior samples into a model store, then a *predict session* serves
//! predictions (with uncertainty) from those samples — no retraining.
//!
//! ```no_run
//! use smurff::prelude::*;
//!
//! // phase 1: train BMF, snapshotting every posterior sample
//! let (train, test) = smurff::data::movielens_like(500, 400, 20_000, 0.2, 42);
//! let cfg = SessionConfig {
//!     num_latent: 16,
//!     burnin: 20,
//!     nsamples: 50,
//!     save_freq: 1,
//!     save_dir: Some("ml_store".into()),
//!     ..Default::default()
//! };
//! let mut session = TrainSession::bmf(train, Some(test), cfg);
//! let result = session.run();
//! println!("RMSE = {:.4}, {} snapshots saved", result.rmse, result.nsnapshots);
//!
//! // phase 2: serve from the store — pointwise with uncertainty, top-K
//! let serve = PredictSession::open(std::path::Path::new("ml_store")).unwrap();
//! let p = serve.predict_one(0, 3, 17);
//! println!("user 3, movie 17: {:.2} ± {:.2}", p.mean, p.std);
//! for (movie, score) in serve.top_k(0, 3, 10, &[]) {
//!     println!("  recommend movie {movie} (score {score:.2})");
//! }
//! ```

pub mod util;
pub mod obs;
pub mod diag;
pub mod rng;
pub mod linalg;
pub mod sparse;
pub mod data;
pub mod noise;
pub mod priors;
pub mod model;
pub mod session;
pub mod coordinator;
pub mod runtime;
pub mod distributed;
pub mod store;
pub mod predict;
pub mod serve;
pub mod baselines;
pub mod hwmodel;
pub mod bench;

/// Convenient glob-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::data::{MatrixConfig, SideInfo, TensorTestSet};
    pub use crate::diag::{ChainMonitor, DiagnosticsReport};
    pub use crate::distributed::{
        DistResult, DistributedSession, FaultPlan, NetSpec, Strategy,
    };
    pub use crate::linalg::Mat;
    pub use crate::noise::NoiseConfig;
    pub use crate::predict::{BlockPrediction, PredictSession, Prediction, ServingModel};
    pub use crate::priors::PriorKind;
    pub use crate::serve::{serve, serve_multi, ServeConfig, ServerHandle};
    pub use crate::session::{
        ModePrior, SessionBuilder, SessionConfig, TrainResult, TrainSession,
    };
    pub use crate::sparse::{SparseMatrix, SparseTensor};
    pub use crate::store::{ModelStore, Snapshot, StoreMeta};
}
