//! # SMURFF-RS — a high-performance framework for Bayesian Matrix Factorization
//!
//! Rust + JAX + Pallas reproduction of *SMURFF: a High-Performance Framework
//! for Matrix Factorization* (Vander Aa et al., 2019).  See `DESIGN.md` for
//! the full system inventory and experiment index.
//!
//! The crate is organised in layers:
//!
//! * substrates: [`util`], [`rng`], [`linalg`], [`sparse`]
//! * framework:  [`data`], [`noise`], [`priors`], [`model`], [`session`]
//! * runtime:    [`coordinator`] (work-stealing parallel Gibbs),
//!               [`runtime`] (PJRT/XLA AOT engine), [`distributed`]
//! * evaluation: [`baselines`] (PyMC3-like, GraphChi-like, GASPI-like),
//!               [`hwmodel`] (Xeon / Xeon Phi / ARM roofline+cache model),
//!               [`bench`] (the harness regenerating every paper figure)
//!
//! ## Quickstart
//!
//! ```no_run
//! use smurff::prelude::*;
//!
//! let (train, test) = smurff::data::movielens_like(500, 400, 20_000, 0.2, 42);
//! let cfg = SessionConfig { num_latent: 16, burnin: 20, nsamples: 50, ..Default::default() };
//! let mut session = TrainSession::bmf(train, Some(test), cfg);
//! let result = session.run();
//! println!("RMSE = {:.4}", result.rmse);
//! ```

pub mod util;
pub mod rng;
pub mod linalg;
pub mod sparse;
pub mod data;
pub mod noise;
pub mod priors;
pub mod model;
pub mod session;
pub mod coordinator;
pub mod runtime;
pub mod distributed;
pub mod baselines;
pub mod hwmodel;
pub mod bench;

/// Convenient glob-import surface for examples and downstream users.
pub mod prelude {
    pub use crate::data::{MatrixConfig, SideInfo};
    pub use crate::linalg::Mat;
    pub use crate::noise::NoiseConfig;
    pub use crate::priors::PriorKind;
    pub use crate::session::{SessionConfig, TrainResult, TrainSession};
    pub use crate::sparse::SparseMatrix;
}
