//! Distributed training: the same BMF composition sharded across worker
//! nodes under each of the three communication strategies, with the
//! per-node byte/time accounting the strong-scaling bench tabulates.
//!
//! Sync allgather replays the single-node chain exactly; bounded-
//! staleness async trades a little freshness for never blocking on the
//! current iteration; posterior propagation only merges row-posterior
//! statistics every R iterations and ships an order of magnitude fewer
//! bytes.
//!
//! Run: `cargo run --release --example distributed_train`

use smurff::data::TestSet;
use smurff::prelude::*;

fn main() {
    let (train, test) = smurff::data::movielens_like(400, 300, 24_000, 0.2, 42);
    let cfg = SessionConfig {
        num_latent: 16,
        burnin: 10,
        nsamples: 20,
        threads: 1,
        ..Default::default()
    };

    // single-node reference
    let mut single = TrainSession::bmf(train.clone(), Some(test.clone()), cfg.clone());
    let r1 = single.run();
    println!("single node: rmse {:.4} in {:.2}s", r1.rmse, r1.train_seconds);

    for strategy in [
        Strategy::Sync,
        Strategy::Async { staleness: 1 },
        Strategy::PosteriorProp { rounds: 4 },
    ] {
        let dist = SessionBuilder::new(cfg.clone())
            .add_view(
                MatrixConfig::SparseUnknown(train.clone()),
                NoiseConfig::default(),
                Some(TestSet::from_sparse(&test)),
            )
            .distributed(4, strategy, NetSpec::cluster())
            .build_distributed();
        let r = dist.run().expect("distributed run failed");
        println!(
            "{:>8} x{} nodes: rmse {:.4} in {:.2}s, {:.2} MB on the wire",
            r.strategy,
            r.nodes,
            r.result.rmse,
            r.result.train_seconds,
            r.total_bytes() as f64 / 1e6
        );
        for c in &r.comm {
            println!(
                "           node {}: {:.2} MB sent, {:.2}s comm / {:.2}s total",
                c.rank,
                c.bytes_sent as f64 / 1e6,
                c.comm_seconds,
                c.seconds
            );
        }
        assert!(
            (r.result.rmse - r1.rmse) / r1.rmse < 0.05,
            "distributed quality must stay within 5% of single node"
        );
    }
}
