//! Recommender-system example: BMF on MovieLens-like ratings with
//! checkpointing, engine selection and probit binary feedback — the
//! "suggestions for movies on Netflix" workload of the paper's intro.
//!
//! Run: `cargo run --release --example movielens_bmf -- [--engine xla]
//!       [--users N] [--movies N] [--nnz N] [--checkpoint dir]`

use smurff::data::{MatrixConfig, TestSet};
use smurff::noise::NoiseConfig;
use smurff::session::{SessionBuilder, SessionConfig, TrainSession};
use smurff::util::cli::Args;

fn main() -> anyhow::Result<()> {
    smurff::util::logger::init_from_env();
    let args = Args::from_env(&[]).map_err(anyhow::Error::msg)?;
    let users = args.get_usize("users", 2_000).map_err(anyhow::Error::msg)?;
    let movies = args.get_usize("movies", 1_500).map_err(anyhow::Error::msg)?;
    let nnz = args.get_usize("nnz", 100_000).map_err(anyhow::Error::msg)?;

    let (train, test) = smurff::data::movielens_like(users, movies, nnz, 0.2, 11);
    println!(
        "ratings: {} train / {} test over {users} users x {movies} movies",
        train.nnz(),
        test.nnz()
    );

    // --- explicit ratings: BMF with adaptive noise
    let cfg = SessionConfig { num_latent: 16, burnin: 20, nsamples: 60, seed: 11, ..Default::default() };
    let mut builder = SessionBuilder::new(cfg).add_view(
        MatrixConfig::SparseUnknown(train.clone()),
        NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 12.0 },
        Some(TestSet::from_sparse(&test)),
    );
    if args.get_str("engine", "native") == "xla" {
        let dir = smurff::runtime::default_artifacts_dir();
        builder = builder.engine(Box::new(smurff::runtime::XlaEngine::new(&dir)?));
    }
    let mut session = builder.build();
    let r = session.run();
    println!(
        "BMF ({}, {} threads): RMSE {:.4} in {:.2}s",
        session.engine_name(),
        session.nthreads(),
        r.rmse,
        r.train_seconds
    );
    if let Some(dir) = args.get("checkpoint") {
        session.checkpoint(std::path::Path::new(dir))?;
        println!("checkpoint saved to {dir} (resume with Checkpoint::load)");
    }

    // --- implicit feedback: binarize (liked = rating >= 4) and use probit noise
    let bin = |m: &smurff::sparse::SparseMatrix| {
        smurff::sparse::SparseMatrix::from_triplets(
            m.nrows(),
            m.ncols(),
            m.triplets().map(|(i, j, v)| (i, j, if v >= 4.0 { 1.0 } else { -1.0 })),
        )
    };
    let cfg = SessionConfig { num_latent: 16, burnin: 20, nsamples: 40, seed: 11, ..Default::default() };
    let mut probit = SessionBuilder::new(cfg)
        .add_view(
            MatrixConfig::SparseUnknown(bin(&train)),
            NoiseConfig::Probit,
            Some(TestSet::from_sparse(&bin(&test))),
        )
        .build();
    let rp = probit.run();
    println!("probit BMF (liked/not-liked): AUC {:.4} in {:.2}s", rp.auc, rp.train_seconds);

    // --- top-5 recommendations for one user from the posterior mean
    let user = 3usize;
    let mut scores: Vec<(usize, f64)> = (0..movies)
        .filter(|&m| train.get(user, m).is_none())
        .map(|m| {
            (m, smurff::linalg::dot(session.u.row(user), session.views[0].col_latents().row(m)))
        })
        .collect();
    scores.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!(
        "top-5 unseen movies for user {user}: {:?}",
        scores.iter().take(5).map(|(m, s)| format!("movie{m} ({s:+.2})")).collect::<Vec<_>>()
    );
    let _ = TrainSession::bmf; // (quickstart shows the one-liner constructor)
    Ok(())
}
