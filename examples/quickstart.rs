//! Quickstart: the paper's "35-line BMF" (§3), through the public API.
//!
//! Factorize a small synthetic ratings matrix with plain BMF and print
//! the held-out RMSE — the minimal thing a SMURFF user does first.
//!
//! Run: `cargo run --release --example quickstart`

use smurff::prelude::*;

fn main() {
    // 1. data: 500 users × 400 movies, 20k ratings, 20% held out
    let (train, test) = smurff::data::movielens_like(500, 400, 20_000, 0.2, 42);
    println!(
        "train: {}x{} with {} ratings; test: {} ratings",
        train.nrows(),
        train.ncols(),
        train.nnz(),
        test.nnz()
    );

    // 2. session: K=16 latent dimensions, 20 burn-in + 80 posterior samples
    let cfg = SessionConfig { num_latent: 16, burnin: 20, nsamples: 80, ..Default::default() };
    let mut session = TrainSession::bmf(train, Some(test), cfg);

    // 3. run the Gibbs sampler
    let result = session.run();

    println!(
        "done in {:.2}s ({} iterations, {} threads)",
        result.train_seconds,
        result.iterations,
        session.nthreads()
    );
    println!("test RMSE = {:.4}", result.rmse);
    assert!(result.rmse < 0.6, "quickstart should fit this easy data");
}
