//! Train → save → serve: SMURFF's two-phase workflow end to end.
//!
//! Phase 1 trains BMF while snapshotting every posterior sample into a
//! model store; phase 2 reopens the store with a `PredictSession` and
//! serves pointwise predictions with uncertainty plus top-K
//! recommendations.  A second pair of phases demonstrates out-of-matrix
//! prediction: a Macau model trained *without* one compound's activities
//! still predicts them from the compound's fingerprint via the link
//! matrix β.
//!
//! Run: `cargo run --release --example predict_serve`

use smurff::prelude::*;

fn main() {
    let base = std::env::temp_dir().join(format!("smurff_predict_serve_{}", std::process::id()));

    // ---- phase 1: train BMF with save-every-sample
    let (train, test) = smurff::data::movielens_like(300, 200, 12_000, 0.2, 42);
    let rated_by_user0: Vec<u32> = train.row(0).0.to_vec();
    let store_dir = base.join("bmf");
    let cfg = SessionConfig {
        num_latent: 16,
        burnin: 10,
        nsamples: 30,
        save_freq: 1,
        save_dir: Some(store_dir.clone()),
        ..Default::default()
    };
    let mut session = TrainSession::bmf(train, Some(test), cfg);
    let result = session.run();
    println!(
        "trained: RMSE {:.4}, {} posterior snapshots in {}",
        result.rmse,
        result.nsnapshots,
        store_dir.display()
    );

    // ---- phase 2: serve from the store.  Training finished by packing
    // the store into the v3 serving artifact, so the session maps the
    // factor panels zero-copy (on unix) instead of deserializing them.
    let serve = PredictSession::open(&store_dir).expect("open model store");
    println!("serving zero-copy from the packed artifact: {}", serve.zero_copy());
    let p = serve.predict_one(0, 0, 5);
    println!("user 0, movie 5: {:.2} ± {:.2} (posterior std over {} samples)", p.mean, p.std, serve.nsamples());
    println!("top-5 unseen movies for user 0:");
    for (movie, score) in serve.top_k(0, 0, 5, &rated_by_user0) {
        println!("  movie {movie:4}  score {score:.3}");
    }
    let block = serve.predict_block(0, 0..4, 0..3);
    println!("4x3 dense block, means:\n{:?}", block.mean);

    // ---- phase 3: Macau with a held-out compound
    let d = smurff::data::chembl_synth(&smurff::data::ChemblSpec {
        compounds: 200,
        proteins: 40,
        nnz: 6_000,
        fp_bits: 128,
        fp_density: 12,
        seed: 42,
        ..Default::default()
    });
    let held_out = 0u32;
    let kept: Vec<(u32, u32, f64)> =
        d.activity.triplets().filter(|t| t.0 != held_out).collect();
    let train_m = SparseMatrix::from_triplets(d.activity.nrows(), d.activity.ncols(), kept);
    let macau_dir = base.join("macau");
    let cfg = SessionConfig {
        num_latent: 8,
        burnin: 15,
        nsamples: 20,
        save_freq: 2,
        save_dir: Some(macau_dir.clone()),
        ..Default::default()
    };
    let mut session =
        TrainSession::macau(train_m.clone(), None, d.fingerprints_sparse.clone(), cfg);
    let result = session.run();
    println!(
        "\nMacau trained without compound {held_out}: {} snapshots",
        result.nsnapshots
    );

    // ---- phase 4: predict the held-out compound from its fingerprint
    let serve = PredictSession::open(&macau_dir).expect("open macau store");
    assert!(serve.has_link());
    let mut features = vec![0.0; 128];
    d.fingerprints_sparse.row_dense(held_out as usize, &mut features);
    let truth: Vec<(u32, f64)> = d
        .activity
        .triplets()
        .filter(|t| t.0 == held_out)
        .map(|t| (t.1, t.2))
        .collect();
    let cols: Vec<u32> = truth.iter().map(|t| t.0).collect();
    let preds = serve.predict_new_row(&features, 0, &cols).expect("out-of-matrix predict");
    let mean = train_m.mean_value();
    let rmse_oom = smurff::model::rmse(
        &preds.iter().map(|p| p.mean).collect::<Vec<_>>(),
        &truth.iter().map(|t| t.1).collect::<Vec<_>>(),
    );
    let rmse_base = smurff::model::rmse(
        &vec![mean; truth.len()],
        &truth.iter().map(|t| t.1).collect::<Vec<_>>(),
    );
    println!(
        "out-of-matrix RMSE for compound {held_out}: {rmse_oom:.3} (global-mean baseline {rmse_base:.3})"
    );
    assert!(rmse_oom < rmse_base, "side information should beat the mean predictor");
}
