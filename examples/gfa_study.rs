//! GFA simulated study (paper §4, reproducing Bunte et al. 2015):
//! factor a multi-view dataset with known group-factor structure and
//! report how well the spike-and-slab prior recovers which factors are
//! shared between which views.
//!
//! Run: `cargo run --release --example gfa_study`

use smurff::data::{gfa_study_data, GfaSpec};
use smurff::session::{SessionConfig, TrainSession};

fn main() {
    smurff::util::logger::init_from_env();
    let spec = GfaSpec::default(); // 3 views, 6 factors: shared/pairwise/private
    println!(
        "== GFA simulated study: {} samples, views with {:?} features, {} true factors ==",
        spec.n, spec.view_cols, spec.k
    );
    for (f, act) in spec.activity.iter().enumerate() {
        let views: Vec<String> = act
            .iter()
            .enumerate()
            .filter(|(_, &a)| a)
            .map(|(v, _)| format!("view{v}"))
            .collect();
        println!("  true factor {f}: active in {}", views.join(", "));
    }

    let d = gfa_study_data(&spec);
    let cfg = SessionConfig {
        num_latent: spec.k + 2, // over-provision: SnS should kill extras
        burnin: 60,
        nsamples: 60,
        seed: 7,
        ..Default::default()
    };
    let mut session = TrainSession::gfa(d.views.clone(), cfg);
    let r = session.run();
    println!(
        "\ntrained {} iterations in {:.2}s ({:.1} ms/iter)",
        r.iterations,
        r.train_seconds,
        1e3 * r.train_seconds / r.iterations as f64
    );

    // recovered activity: column energy of each view's loading matrix
    println!("\nrecovered factor activity (column energy share per view):");
    println!("{:>9} | view0  view1  view2", "component");
    let k = session.u.cols();
    for kk in 0..k {
        let mut row = format!("{kk:>9} |");
        for v in 0..session.views.len() {
            let w = session.views[v].col_latents();
            let e: f64 = (0..w.rows()).map(|j| w[(j, kk)] * w[(j, kk)]).sum();
            let total: f64 = (0..k)
                .map(|c| (0..w.rows()).map(|j| w[(j, c)] * w[(j, c)]).sum::<f64>())
                .sum();
            row.push_str(&format!(" {:5.1}%", 100.0 * e / total.max(1e-12)));
        }
        println!("{row}");
    }

    // reconstruction quality per view
    println!("\nreconstruction relative error per view:");
    for (v, x_true) in d.views.iter().enumerate() {
        let recon = smurff::linalg::gemm(&session.u, &session.views[v].col_latents().transpose());
        let mut diff = recon;
        diff.axpy(-1.0, x_true);
        println!("  view{v}: {:.4}", diff.norm() / x_true.norm());
    }
    println!("\n(the original R implementation of this study is ~100x slower — see `cargo bench --bench gfa_study`)");
}
