//! End-to-end driver (DESIGN.md §3): compound-activity prediction with
//! Macau on a ChEMBL-scale synthetic dataset, through the full stack —
//! coordinator → engine (native Rust or AOT-compiled XLA artifacts) →
//! priors/noise — logging the RMSE trajectory and sustained throughput.
//!
//! Defaults: 20 000 compounds × 1 000 proteins, ~1 M observed IC50
//! cells, K = 16, 40 burn-in + 160 sampling iterations.  Scale with
//! flags, e.g.:
//!
//!   cargo run --release --example chembl_activity -- --compounds 2000 \
//!       --proteins 200 --nnz 100000 --iters 60 --engine xla

use smurff::data::{chembl_synth, split_train_test, ChemblSpec, MatrixConfig, TestSet};
use smurff::noise::NoiseConfig;
use smurff::session::{SessionBuilder, SessionConfig};
use smurff::util::cli::Args;
use smurff::util::Timer;

fn main() -> anyhow::Result<()> {
    smurff::util::logger::init_from_env();
    let args = Args::from_env(&["help"]).map_err(anyhow::Error::msg)?;
    if args.get_bool("help") {
        println!("flags: --compounds N --proteins N --nnz N --k N --iters N --threads N --engine native|xla --seed N");
        return Ok(());
    }
    let compounds = args.get_usize("compounds", 20_000).map_err(anyhow::Error::msg)?;
    let proteins = args.get_usize("proteins", 1_000).map_err(anyhow::Error::msg)?;
    let nnz = args.get_usize("nnz", 1_000_000).map_err(anyhow::Error::msg)?;
    let k = args.get_usize("k", 16).map_err(anyhow::Error::msg)?;
    let iters = args.get_usize("iters", 200).map_err(anyhow::Error::msg)?;
    let seed = args.get_usize("seed", 42).map_err(anyhow::Error::msg)? as u64;
    let engine = args.get_str("engine", "native");

    println!("== generating ChEMBL-like dataset ({compounds} x {proteins}, ~{nnz} IC50 cells) ==");
    let t = Timer::start();
    let spec = ChemblSpec {
        compounds,
        proteins,
        nnz,
        fp_bits: 1024,
        fp_density: 40,
        seed,
        ..Default::default()
    };
    let d = chembl_synth(&spec);
    let (train, test) = split_train_test(&d.activity, 0.2, seed);
    println!(
        "generated in {:.1}s: {} train / {} test cells, {} fingerprint bits set",
        t.elapsed_s(),
        train.nnz(),
        test.nnz(),
        match &d.fingerprints_sparse {
            smurff::data::SideInfo::Sparse(s) => s.nnz(),
            _ => 0,
        }
    );

    let cfg = SessionConfig {
        num_latent: k,
        burnin: iters / 5,
        nsamples: iters - iters / 5,
        seed,
        threads: args.get_usize("threads", 0).map_err(anyhow::Error::msg)?,
        ..Default::default()
    };
    let mut builder = SessionBuilder::new(cfg.clone())
        .row_macau(d.fingerprints_sparse.clone())
        .add_view(
            MatrixConfig::SparseUnknown(train.clone()),
            NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 },
            Some(TestSet::from_sparse(&test)),
        );
    if engine == "xla" {
        let dir = smurff::runtime::default_artifacts_dir();
        builder = builder.engine(Box::new(smurff::runtime::XlaEngine::new(&dir)?));
        println!("using XLA engine with artifacts from {}", dir.display());
    }
    let mut session = builder.build();
    println!(
        "== training Macau: K={k}, {} iterations, {} threads, engine={} ==",
        iters,
        session.nthreads(),
        session.engine_name()
    );

    let train_timer = Timer::start();
    let total = cfg.burnin + cfg.nsamples;
    let mut last_report = Timer::start();
    for it in 0..total {
        session.step();
        if last_report.elapsed_s() > 2.0 || it + 1 == total || it < 3 {
            let phase = if it < cfg.burnin { "burnin" } else { "sample" };
            println!(
                "iter {:4}/{total} [{phase}]  rmse={:.4}  noise α={:.3}  λ_β snapshot: {}",
                it + 1,
                session.view_rmse(0),
                session.views[0].noise.alpha(),
                session.row_prior.describe(),
            );
            last_report = Timer::start();
        }
    }
    let secs = train_timer.elapsed_s();
    let result_rmse = session.view_rmse(0);

    // throughput: the paper-relevant unit is Gram-update work, nnz·K² per side sweep
    let updates = 2.0 * train.nnz() as f64 * (k * k) as f64 * total as f64;
    println!("\n== results ==");
    println!("total time       : {secs:.2}s  ({:.1} ms/iteration)", 1e3 * secs / total as f64);
    println!("throughput       : {:.2} G gram-MACs/s", 2.0 * updates / secs / 1e9);
    println!("final test RMSE  : {result_rmse:.4}");

    // compare against the no-side-info baseline at reduced iterations
    let quick_cfg = SessionConfig { burnin: iters / 10, nsamples: iters / 5, ..cfg };
    let mut bmf = SessionBuilder::new(quick_cfg)
        .add_view(
            MatrixConfig::SparseUnknown(train),
            NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 10.0 },
            Some(TestSet::from_sparse(&test)),
        )
        .build();
    let bmf_rmse = bmf.run().rmse;
    println!("BMF (short run)  : {bmf_rmse:.4}  (side information gain: {:+.1}%)",
        100.0 * (bmf_rmse - result_rmse) / bmf_rmse);
    Ok(())
}
