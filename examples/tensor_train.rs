//! 3-mode tensor factorization end to end: generate a synthetic CP
//! tensor (compound × target × assay-condition, the upstream system's
//! flagship workload shape), train with per-mode Normal priors while
//! snapshotting every posterior sample, then serve the store with a
//! `PredictSession` — pointwise mean ± std at a coordinate tuple and
//! top-K over one free mode.
//!
//! Run with: `cargo run --release --example tensor_train`

use smurff::data::{cp_tensor_synth, split_tensor_train_test, CpSpec, TensorTestSet};
use smurff::noise::NoiseConfig;
use smurff::predict::PredictSession;
use smurff::session::{ModePrior, SessionBuilder, SessionConfig};

fn main() -> anyhow::Result<()> {
    // --- phase 0: a synthetic rank-4 CP tensor with 10% noise
    let spec = CpSpec { dims: vec![80, 60, 40], rank: 4, nnz: 25_000, noise: 0.1, seed: 42 };
    let d = cp_tensor_synth(&spec);
    let (train, test) = split_tensor_train_test(&d.tensor, 0.2, 42);
    println!(
        "tensor: {:?} dims, {} observed cells ({} train / {} test)",
        d.tensor.dims(),
        d.tensor.nnz(),
        train.nnz(),
        test.nnz()
    );

    // --- phase 1: Gibbs training, one Normal prior per non-shared mode
    let store_dir = std::env::temp_dir().join("smurff_tensor_example_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let cfg = SessionConfig {
        num_latent: 8,
        burnin: 20,
        nsamples: 40,
        seed: 42,
        save_freq: 2,
        save_dir: Some(store_dir.clone()),
        verbose: true,
        ..Default::default()
    };
    let mut session = SessionBuilder::new(cfg)
        .tensor_view(
            train,
            vec![ModePrior::Normal, ModePrior::Normal],
            NoiseConfig::Adaptive { sn_init: 1.0, sn_max: 20.0 },
            Some(TensorTestSet::from_tensor(&test)),
        )
        .build();
    let result = session.run();
    println!(
        "trained: RMSE {:.4} (noise floor {:.2}), {} snapshots in {}",
        result.rmse,
        spec.noise,
        result.nsnapshots,
        store_dir.display()
    );

    // --- phase 2: serve the posterior store
    let serve = PredictSession::open(&store_dir)?;
    println!(
        "serving {} posterior samples of a {}-mode view",
        serve.nsamples(),
        serve.nmodes(0)
    );
    let p = serve.predict_coords(0, &[3, 17, 5]);
    println!("cell (compound 3, target 17, condition 5): {:.3} ± {:.3}", p.mean, p.std);
    // top-5 targets for compound 3 under condition 5 (mode 1 free)
    for (rank, (target, score)) in serve.top_k_mode(0, &[3, 0, 5], 1, 5, &[]).iter().enumerate() {
        println!("  #{:<2} target {:3}  score {score:.3}", rank + 1, target);
    }
    Ok(())
}
